//! The training loop: device-resident state, prefetched batches, periodic
//! validation — the L3 hot path.
//!
//! Per step: upload (x, y) (assembled off-thread by the prefetcher), call
//! the compiled train artifact with `[state..., x, y, lr]` buffers, swap
//! the returned state buffers in place of the old ones, fetch the scalar
//! loss/acc. State tensors never touch the host except for checkpoints
//! and the final summary.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::checkpoint::{Checkpoint, CheckpointStore};
use super::config::RunConfig;
use super::metrics::{EvalRecord, History, RecoveryAction, RecoveryEvent, RecoveryKind, StepRecord};
use super::resilient::EXPLOSION_THRESHOLD;
use crate::bfp::{next_wider_class, BfpContext, Rounding, TileSize};
use crate::data::{prefetch::Prefetcher, DatasetCache};
use crate::runtime::{fetch_f32, fetch_scalar_f32, Engine, HostTensor, Manifest, Role};
use crate::util::rng::{SplitMix64, Xorshift32};

/// Outcome of one run.
pub struct RunResult {
    pub config: RunConfig,
    pub history: History,
    pub final_error: f32,
    pub final_loss: f32,
    pub diverged: bool,
    pub train_secs: f64,
    pub compile_secs: f64,
}

impl RunResult {
    pub fn summary_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("final_error", Json::num(self.final_error)),
            ("final_loss", Json::num(self.final_loss)),
            ("diverged", Json::Bool(self.diverged)),
            ("train_secs", Json::num(self.train_secs)),
            ("steps_per_sec", Json::num(self.history.throughput().unwrap_or(0.0))),
            ("history", self.history.to_json()),
        ])
    }
}

pub struct Trainer {
    pub engine: Engine,
    pub manifest: Arc<Manifest>,
    /// Generated datasets shared across runs: a sweep training many
    /// numeric configs of the same combo reuses one dataset instead of
    /// regenerating it per run.
    pub datasets: DatasetCache,
}

impl Trainer {
    pub fn new(manifest: Arc<Manifest>) -> Result<Trainer> {
        Ok(Trainer { engine: Engine::new()?, manifest, datasets: DatasetCache::default() })
    }

    /// Train one combo per the run config. Evaluation runs on the same
    /// device-resident state buffers.
    pub fn run(&self, cfg: &RunConfig) -> Result<RunResult> {
        let t_all = Instant::now();
        let train_art = self.manifest.artifact(&cfg.combo, Role::Train)?;
        let eval_art = self.manifest.artifact(&cfg.combo, Role::Eval)?;
        let init_art = self.manifest.artifact(&cfg.combo, Role::Init)?;
        let dataset_spec = self.manifest.dataset(&train_art.dataset)?;
        let batch = train_art.batch;
        let state_len = train_art.state_len;

        // Compile all three programs up front.
        let train_prog = self.engine.load(train_art)?;
        let eval_prog = self.engine.load(eval_art)?;
        let init_prog = self.engine.load(init_art)?;
        let compile_secs =
            train_prog.compile_secs + eval_prog.compile_secs + init_prog.compile_secs;

        // Initialize state from the seed.
        let mut state = init_prog
            .run_host(&[HostTensor::scalar_i32(cfg.seed as i32)])
            .context("running init")?;
        debug_assert_eq!(state.len(), state_len);

        // Dataset (cached across runs — sweeps reuse one generated copy
        // per (spec, seed)) + prefetching batch producer at the
        // configured depth.
        let dataset = self.datasets.get_or_generate(dataset_spec, cfg.seed ^ 0xda7a)?;
        let prefetch = {
            let ds = dataset.clone();
            let mut rng = SplitMix64::new(cfg.seed.wrapping_mul(0x9e37).wrapping_add(1));
            Prefetcher::spawn(cfg.prefetch_depth.max(1), move || ds.train_batch(batch, &mut rng))
        };
        let val_batches: Vec<(HostTensor, HostTensor)> = dataset.val_batches(batch);

        // Host-side FP→BFP input converter (deterministic per seed),
        // configured once for the whole run: the hardware quantizes
        // activations at the array boundary; with `input_bfp` set we
        // model that on the batch before upload, using the band-parallel
        // in-place round-trip (no mantissa tensor is materialized). The
        // BfpContext resolves thread budget + tile once, outside the
        // step loop.
        let mut input_conv = cfg.input_bfp.map(|(bits, tile_edge)| {
            let seed = SplitMix64::new(cfg.seed ^ 0xB0F0_C04E_7E27_ED01).next_u32();
            let ctx = BfpContext::from_env().with_tile(TileSize::Edge(tile_edge));
            (bits, ctx, Xorshift32::new(seed))
        });

        // Fault tolerance: a rotating crash-safe checkpoint store (when
        // periodic checkpointing or the watchdog is on) plus the initial
        // state snapshot as the restart fallback. The prefetcher is a
        // stream, so a rolled-back trainer replays the *schedule* (step
        // indices, lr), not the exact batches — recovery here is about
        // rescuing the run, not bit-exact replay (the resilient demo loop
        // covers that).
        let specs = &train_art.inputs[..state_len];
        let watchdog = cfg.max_recoveries > 0;
        let store = if watchdog || cfg.checkpoint_every > 0 {
            cfg.checkpoint_dir
                .as_ref()
                .map(|d| CheckpointStore::new(d.clone(), cfg.combo.clone()))
        } else {
            None
        };
        let snapshot = |state: &[xla::Literal]| -> Result<Vec<HostTensor>> {
            state
                .iter()
                .zip(specs)
                .map(|(buf, spec)| {
                    // all state leaves are f32 today (params/momentum/BN)
                    let v = fetch_f32(buf)
                        .with_context(|| format!("fetching state leaf {:?}", spec.name))?;
                    Ok(HostTensor::F32(v, spec.shape.clone()))
                })
                .collect()
        };
        let initial = if watchdog { Some(snapshot(&state)?) } else { None };
        let restore = |leaves: &[HostTensor]| -> Result<Vec<xla::Literal>> {
            leaves.iter().map(|l| l.to_literal()).collect()
        };

        let mut history = History::default();
        let mut recoveries_used = 0usize;
        let mut step = 0usize;

        // Crash-safe resume: pick up from the newest checkpoint that
        // passes CRC + manifest validation (corrupt ones are skipped with
        // a warning inside the store, never trusted).
        if let Some(store) = &store {
            if let Some((ck, path)) = store.load_newest_valid(&cfg.combo, specs)? {
                state = restore(&ck.leaves)?;
                step = ck.step;
                log::info!("{}: resumed from {path:?} at step {step}", cfg.combo);
            }
        }

        let t_train = Instant::now();
        while step < cfg.steps {
            let lr = cfg.lr.at(step);
            let t0 = Instant::now();
            let (mut x, y) = prefetch.next();
            if let Some((bits, ctx, rng)) = &mut input_conv {
                quantize_input(&mut x, *bits, ctx, rng)?;
            }
            let xb = x.to_literal()?;
            let yb = y.to_literal()?;
            let lrb = HostTensor::scalar_f32(lr).to_literal()?;

            // args = state ++ [x, y, lr]
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&xb);
            args.push(&yb);
            args.push(&lrb);
            let mut out = train_prog.run(&args)?;

            // swap in new state; trailing outputs are loss, acc
            let acc_buf = out.pop().context("missing acc output")?;
            let loss_buf = out.pop().context("missing loss output")?;
            state = out;

            let record = step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps;
            if watchdog || record {
                let loss = fetch_scalar_f32(&loss_buf)?;
                let hazard = if !loss.is_finite() {
                    Some(RecoveryKind::NonFiniteLoss)
                } else if loss > EXPLOSION_THRESHOLD {
                    Some(RecoveryKind::ExplodingLoss)
                } else {
                    None
                };
                if watchdog {
                    if let Some(kind) = hazard {
                        recoveries_used += 1;
                        let detail = format!("loss={loss}");
                        if recoveries_used > cfg.max_recoveries {
                            history.recoveries.push(RecoveryEvent {
                                step,
                                kind,
                                action: RecoveryAction::Abort,
                                detail: detail.clone(),
                            });
                            return Err(anyhow::anyhow!(
                                "{}: recovery budget ({}) exhausted at step {step} ({}): {detail}",
                                cfg.combo,
                                cfg.max_recoveries,
                                kind.name()
                            ));
                        }
                        // roll back to the newest valid checkpoint, else
                        // restart from the initial state; widen the input
                        // converter's mantissa class either way.
                        let restored = match &store {
                            Some(store) => store.load_newest_valid(&cfg.combo, specs)?,
                            None => None,
                        };
                        let (action, resume) = match restored {
                            Some((ck, _)) => {
                                state = restore(&ck.leaves)?;
                                (RecoveryAction::Rollback, ck.step)
                            }
                            None => {
                                state = restore(initial.as_ref().expect("watchdog snapshot"))?;
                                (RecoveryAction::Restart, 0)
                            }
                        };
                        let mut action = action;
                        let mut width_note = String::new();
                        if let Some((bits, _, _)) = &mut input_conv {
                            if let Some(w) = next_wider_class(*bits) {
                                width_note = format!("; input width {} -> {w}", *bits);
                                *bits = w;
                                if action == RecoveryAction::Rollback {
                                    action = RecoveryAction::RollbackWiden;
                                }
                            }
                        }
                        log::warn!(
                            "{}: {} at step {step} ({detail}); {} to step {resume}{width_note}",
                            cfg.combo,
                            kind.name(),
                            action.name()
                        );
                        history.recoveries.push(RecoveryEvent {
                            step,
                            kind,
                            action,
                            detail: format!("{detail}{width_note}; resumed at step {resume}"),
                        });
                        history.steps.retain(|r| r.step < resume);
                        history.evals.retain(|e| e.step <= resume);
                        step = resume;
                        continue;
                    }
                }
                if record {
                    let acc = fetch_scalar_f32(&acc_buf)?;
                    history.steps.push(StepRecord {
                        step,
                        loss,
                        acc,
                        lr,
                        step_secs: t0.elapsed().as_secs_f64(),
                    });
                    if !watchdog && !loss.is_finite() {
                        log::warn!("{}: diverged at step {step} (loss {loss})", cfg.combo);
                        break;
                    }
                }
            }

            step += 1;
            if let Some(store) = &store {
                if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
                    let ck = Checkpoint {
                        combo: cfg.combo.clone(),
                        step,
                        leaves: snapshot(&state)?,
                    };
                    store.save(&ck, specs)?;
                }
            }

            let do_eval = cfg.eval_every > 0 && step % cfg.eval_every == 0;
            if do_eval && step != cfg.steps {
                let ev = self.evaluate(&eval_prog, &state, &val_batches, step)?;
                log::info!(
                    "{} step {}: val loss {:.4} err {:.2}%",
                    cfg.combo,
                    step,
                    ev.loss,
                    ev.error * 100.0
                );
                history.evals.push(ev);
            }
        }
        // Final evaluation always.
        let final_ev = self.evaluate(&eval_prog, &state, &val_batches, cfg.steps)?;
        history.evals.push(final_ev);
        let train_secs = t_train.elapsed().as_secs_f64();

        // Optional checkpoint of the final state (rotated through the
        // store when periodic checkpointing is on, so `prev` survives) —
        // skipped when the cadence just wrote one at this exact step.
        if let Some(dir) = &cfg.checkpoint_dir {
            let already_saved =
                cfg.checkpoint_every > 0 && step > 0 && step % cfg.checkpoint_every == 0;
            if !already_saved {
                let ck = Checkpoint {
                    combo: cfg.combo.clone(),
                    step: cfg.steps,
                    leaves: snapshot(&state)?,
                };
                let path = dir.join(format!("{}.ckpt", cfg.combo));
                match &store {
                    Some(store) => store.save(&ck, specs)?,
                    None => ck.save(&path, specs)?,
                }
                log::info!("checkpoint written to {path:?}");
            }
        }

        log::info!(
            "{}: done in {:.1}s (+{:.1}s compile), final err {:.2}%",
            cfg.combo,
            train_secs,
            compile_secs,
            final_ev.error * 100.0
        );
        let _ = t_all;
        let diverged = history.diverged();
        Ok(RunResult {
            config: cfg.clone(),
            final_error: final_ev.error,
            final_loss: final_ev.loss,
            diverged,
            history,
            train_secs,
            compile_secs,
        })
    }

    fn evaluate(
        &self,
        eval_prog: &crate::runtime::Program,
        state: &[xla::Literal],
        val_batches: &[(HostTensor, HostTensor)],
        step: usize,
    ) -> Result<EvalRecord> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        for (x, y) in val_batches {
            let xb = x.to_literal()?;
            let yb = y.to_literal()?;
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&xb);
            args.push(&yb);
            let out = eval_prog.run(&args)?;
            loss_sum += fetch_scalar_f32(&out[0])? as f64;
            correct += fetch_scalar_f32(&out[1])? as f64;
            total += x.shape()[0] as f64;
        }
        Ok(EvalRecord {
            step,
            loss: (loss_sum / total.max(1.0)) as f32,
            error: (1.0 - correct / total.max(1.0)) as f32,
        })
    }
}

/// Quantize a batch tensor through a BFP round-trip, flattened to
/// `[batch, features]` so tiles never span examples (each converter lane
/// sees one example at a time). Integer tensors (labels) pass through.
/// The context (tile size + thread budget) is resolved once per run.
fn quantize_input(
    x: &mut HostTensor,
    mantissa_bits: u32,
    ctx: &BfpContext,
    rng: &mut Xorshift32,
) -> Result<()> {
    if let HostTensor::F32(v, shape) = x {
        let rows = shape.first().copied().unwrap_or(1).max(1);
        if v.len() % rows != 0 {
            return Err(anyhow::anyhow!(
                "input tensor len {} not divisible by batch {rows}",
                v.len()
            ));
        }
        let cols = v.len() / rows;
        ctx.quantize_inplace(v, rows, cols, mantissa_bits, &mut Rounding::Stochastic(rng))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::quant_report;

    fn conv_ctx(tile_edge: usize) -> BfpContext {
        BfpContext::from_env().with_tile(TileSize::Edge(tile_edge))
    }

    #[test]
    fn quantize_input_roundtrips_f32_batches() {
        // 4 examples x 32 features, off the 8-bit grid (multiples of 1/7)
        let rows = 4;
        let cols = 32;
        let data: Vec<f32> =
            (0..rows * cols).map(|i| ((i * 37 % 101) as f32) / 7.0 - 7.0).collect();
        let mut x = HostTensor::F32(data.clone(), vec![rows, cols]);
        let mut rng = Xorshift32::new(5);
        quantize_input(&mut x, 8, &conv_ctx(16), &mut rng).unwrap();
        let HostTensor::F32(q, _) = &x else { panic!("dtype changed") };
        assert_ne!(q, &data, "8-bit round-trip must move off-grid values");
        // sanity: 8-bit distortion on this data is small but nonzero
        let report = quant_report(&data, rows, cols, 8, TileSize::Edge(16)).unwrap();
        assert!(report.max_rel_err < 0.05 && report.snr_db > 20.0);

        // determinism: same seed, same result
        let mut x2 = HostTensor::F32(data.clone(), vec![rows, cols]);
        let mut rng2 = Xorshift32::new(5);
        quantize_input(&mut x2, 8, &conv_ctx(16), &mut rng2).unwrap();
        assert_eq!(x, x2);
    }

    #[test]
    fn quantize_input_leaves_labels_alone() {
        let mut y = HostTensor::I32(vec![1, 2, 3], vec![3]);
        let orig = y.clone();
        let mut rng = Xorshift32::new(1);
        quantize_input(&mut y, 8, &conv_ctx(16), &mut rng).unwrap();
        assert_eq!(y, orig);
    }
}
