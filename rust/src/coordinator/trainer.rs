//! The training loop: device-resident state, prefetched batches, periodic
//! validation — the L3 hot path.
//!
//! Per step: upload (x, y) (assembled off-thread by the prefetcher), call
//! the compiled train artifact with `[state..., x, y, lr]` buffers, swap
//! the returned state buffers in place of the old ones, fetch the scalar
//! loss/acc. State tensors never touch the host except for checkpoints
//! and the final summary.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::checkpoint::Checkpoint;
use super::config::RunConfig;
use super::metrics::{EvalRecord, History, StepRecord};
use crate::data::{prefetch::Prefetcher, Dataset};
use crate::runtime::{fetch_f32, fetch_scalar_f32, Engine, HostTensor, Manifest, Role};
use crate::util::rng::SplitMix64;

/// Outcome of one run.
pub struct RunResult {
    pub config: RunConfig,
    pub history: History,
    pub final_error: f32,
    pub final_loss: f32,
    pub diverged: bool,
    pub train_secs: f64,
    pub compile_secs: f64,
}

impl RunResult {
    pub fn summary_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("final_error", Json::num(self.final_error)),
            ("final_loss", Json::num(self.final_loss)),
            ("diverged", Json::Bool(self.diverged)),
            ("train_secs", Json::num(self.train_secs)),
            ("steps_per_sec", Json::num(self.history.throughput().unwrap_or(0.0))),
            ("history", self.history.to_json()),
        ])
    }
}

pub struct Trainer {
    pub engine: Engine,
    pub manifest: Arc<Manifest>,
}

impl Trainer {
    pub fn new(manifest: Arc<Manifest>) -> Result<Trainer> {
        Ok(Trainer { engine: Engine::new()?, manifest })
    }

    /// Train one combo per the run config. Evaluation runs on the same
    /// device-resident state buffers.
    pub fn run(&self, cfg: &RunConfig) -> Result<RunResult> {
        let t_all = Instant::now();
        let train_art = self.manifest.artifact(&cfg.combo, Role::Train)?;
        let eval_art = self.manifest.artifact(&cfg.combo, Role::Eval)?;
        let init_art = self.manifest.artifact(&cfg.combo, Role::Init)?;
        let dataset_spec = self.manifest.dataset(&train_art.dataset)?;
        let batch = train_art.batch;
        let state_len = train_art.state_len;

        // Compile all three programs up front.
        let train_prog = self.engine.load(train_art)?;
        let eval_prog = self.engine.load(eval_art)?;
        let init_prog = self.engine.load(init_art)?;
        let compile_secs =
            train_prog.compile_secs + eval_prog.compile_secs + init_prog.compile_secs;

        // Initialize state from the seed.
        let mut state = init_prog
            .run_host(&[HostTensor::scalar_i32(cfg.seed as i32)])
            .context("running init")?;
        debug_assert_eq!(state.len(), state_len);

        // Dataset + prefetching batch producer.
        let dataset = Arc::new(Dataset::from_spec(dataset_spec, cfg.seed ^ 0xda7a)?);
        let prefetch = {
            let ds = dataset.clone();
            let mut rng = SplitMix64::new(cfg.seed.wrapping_mul(0x9e37).wrapping_add(1));
            Prefetcher::spawn(2, move || ds.train_batch(batch, &mut rng))
        };
        let val_batches: Vec<(HostTensor, HostTensor)> = dataset.val_batches(batch);

        let mut history = History::default();
        let t_train = Instant::now();
        for step in 0..cfg.steps {
            let lr = cfg.lr.at(step);
            let t0 = Instant::now();
            let (x, y) = prefetch.next();
            let xb = x.to_literal()?;
            let yb = y.to_literal()?;
            let lrb = HostTensor::scalar_f32(lr).to_literal()?;

            // args = state ++ [x, y, lr]
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&xb);
            args.push(&yb);
            args.push(&lrb);
            let mut out = train_prog.run(&args)?;

            // swap in new state; trailing outputs are loss, acc
            let acc_buf = out.pop().context("missing acc output")?;
            let loss_buf = out.pop().context("missing loss output")?;
            state = out;

            let record = step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps;
            if record {
                let loss = fetch_scalar_f32(&loss_buf)?;
                let acc = fetch_scalar_f32(&acc_buf)?;
                history.steps.push(StepRecord {
                    step,
                    loss,
                    acc,
                    lr,
                    step_secs: t0.elapsed().as_secs_f64(),
                });
                if !loss.is_finite() {
                    log::warn!("{}: diverged at step {step} (loss {loss})", cfg.combo);
                    break;
                }
            }

            let do_eval = cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0;
            if do_eval && step + 1 != cfg.steps {
                let ev = self.evaluate(&eval_prog, &state, &val_batches, step + 1)?;
                log::info!(
                    "{} step {}: val loss {:.4} err {:.2}%",
                    cfg.combo,
                    step + 1,
                    ev.loss,
                    ev.error * 100.0
                );
                history.evals.push(ev);
            }
        }
        // Final evaluation always.
        let final_ev = self.evaluate(&eval_prog, &state, &val_batches, cfg.steps)?;
        history.evals.push(final_ev);
        let train_secs = t_train.elapsed().as_secs_f64();

        // Optional checkpoint of the final state.
        if let Some(dir) = &cfg.checkpoint_dir {
            let leaves = state
                .iter()
                .zip(&train_art.inputs[..state_len])
                .map(|(buf, spec)| {
                    // all state leaves are f32 today (params/momentum/BN)
                    let v = fetch_f32(buf)
                        .with_context(|| format!("fetching state leaf {:?}", spec.name))?;
                    Ok(HostTensor::F32(v, spec.shape.clone()))
                })
                .collect::<Result<Vec<_>>>()?;
            let ck = Checkpoint { combo: cfg.combo.clone(), step: cfg.steps, leaves };
            let path = dir.join(format!("{}.ckpt", cfg.combo));
            ck.save(&path, &train_art.inputs[..state_len])?;
            log::info!("checkpoint written to {path:?}");
        }

        log::info!(
            "{}: done in {:.1}s (+{:.1}s compile), final err {:.2}%",
            cfg.combo,
            train_secs,
            compile_secs,
            final_ev.error * 100.0
        );
        let _ = t_all;
        let diverged = history.diverged();
        Ok(RunResult {
            config: cfg.clone(),
            final_error: final_ev.error,
            final_loss: final_ev.loss,
            diverged,
            history,
            train_secs,
            compile_secs,
        })
    }

    fn evaluate(
        &self,
        eval_prog: &crate::runtime::Program,
        state: &[xla::Literal],
        val_batches: &[(HostTensor, HostTensor)],
        step: usize,
    ) -> Result<EvalRecord> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        for (x, y) in val_batches {
            let xb = x.to_literal()?;
            let yb = y.to_literal()?;
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&xb);
            args.push(&yb);
            let out = eval_prog.run(&args)?;
            loss_sum += fetch_scalar_f32(&out[0])? as f64;
            correct += fetch_scalar_f32(&out[1])? as f64;
            total += x.shape()[0] as f64;
        }
        Ok(EvalRecord {
            step,
            loss: (loss_sum / total.max(1.0)) as f32,
            error: (1.0 - correct / total.max(1.0)) as f32,
        })
    }
}
