//! Experiment sweep runner: execute a list of RunConfigs, persist each
//! result (JSON summary + CSV curve) under `results/`, and collect the
//! summary rows the repro harnesses print.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::config::RunConfig;
use super::trainer::{RunResult, Trainer};
use crate::runtime::Manifest;
use crate::util::json::Json;

/// One sweep entry result, kept lightweight for table assembly.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub combo: String,
    pub final_error: f32,
    pub final_loss: f32,
    pub perplexity: f32,
    pub diverged: bool,
    pub steps_per_sec: f64,
}

impl SweepRow {
    fn from(r: &RunResult) -> SweepRow {
        SweepRow {
            combo: r.config.combo.clone(),
            final_error: r.final_error,
            final_loss: r.final_loss,
            perplexity: r.final_loss.exp(),
            diverged: r.diverged,
            steps_per_sec: r.history.throughput().unwrap_or(0.0),
        }
    }
}

pub struct Sweep {
    pub trainer: Trainer,
    pub results_dir: PathBuf,
}

impl Sweep {
    pub fn new(manifest: Arc<Manifest>, results_dir: &Path) -> Result<Sweep> {
        std::fs::create_dir_all(results_dir)
            .with_context(|| format!("creating {results_dir:?}"))?;
        Ok(Sweep { trainer: Trainer::new(manifest)?, results_dir: results_dir.to_path_buf() })
    }

    /// Run every config sequentially (XLA's CPU backend already uses all
    /// cores intra-op; running combos in parallel would just contend),
    /// persisting as we go so partial sweeps are usable. Datasets are
    /// generated once per (spec, seed) and reused across combos via the
    /// trainer's [`crate::data::DatasetCache`] — a mantissa/tile sweep
    /// over one dataset no longer regenerates it per numeric config.
    pub fn run_all(&self, configs: &[RunConfig]) -> Result<Vec<SweepRow>> {
        let mut rows = Vec::with_capacity(configs.len());
        for (i, cfg) in configs.iter().enumerate() {
            // Reuse cached result if present (idempotent sweeps: delete
            // results/ to force a rerun).
            let tag = if cfg.eval_every > 0 {
                format!("{}_s{}_n{}_e{}", cfg.combo, cfg.seed, cfg.steps, cfg.eval_every)
            } else {
                format!("{}_s{}_n{}", cfg.combo, cfg.seed, cfg.steps)
            };
            let json_path = self.results_dir.join(format!("{tag}.json"));
            if let Some(row) = load_cached(&json_path, cfg) {
                log::info!("[{}/{}] {tag}: cached", i + 1, configs.len());
                rows.push(row);
                continue;
            }
            log::info!("[{}/{}] {tag}: training {} steps", i + 1, configs.len(), cfg.steps);
            let result = self.trainer.run(cfg)?;
            result
                .history
                .write_csv(&self.results_dir.join(format!("{tag}.csv")))?;
            std::fs::write(&json_path, result.summary_json().to_string())
                .with_context(|| format!("writing {json_path:?}"))?;
            rows.push(SweepRow::from(&result));
        }
        log::debug!(
            "sweep: {} runs shared {} generated dataset(s)",
            configs.len(),
            self.trainer.datasets.len()
        );
        Ok(rows)
    }
}

fn load_cached(path: &Path, cfg: &RunConfig) -> Option<SweepRow> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    let loss = j.get("final_loss")?.as_f64()? as f32;
    Some(SweepRow {
        combo: cfg.combo.clone(),
        final_error: j.get("final_error")?.as_f64()? as f32,
        final_loss: loss,
        perplexity: loss.exp(),
        diverged: j.get("diverged")?.as_bool()?,
        steps_per_sec: j.get("steps_per_sec")?.as_f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cached_row_roundtrip() {
        let dir = std::env::temp_dir().join("hbfp_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.json");
        std::fs::write(
            &p,
            r#"{"final_error": 0.25, "final_loss": 1.5, "diverged": false, "steps_per_sec": 3.2}"#,
        )
        .unwrap();
        let cfg = RunConfig::new("m-d-fp32", 10);
        let row = load_cached(&p, &cfg).unwrap();
        assert_eq!(row.final_error, 0.25);
        assert!(!row.diverged);
        assert!(load_cached(&dir.join("missing.json"), &cfg).is_none());
    }
}
