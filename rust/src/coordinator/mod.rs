//! L3 coordinator: experiment configuration, the training loop, metric
//! collection, checkpointing, sweep scheduling, and the per-table/figure
//! reproduction harnesses (`repro`).

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod report;
pub mod repro;
pub mod resilient;
pub mod sweep;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointStore, CkptError};
pub use config::{default_base_lr, parse_schedule, LrSchedule, RunConfig, DEFAULT_PREFETCH_DEPTH};
pub use metrics::{
    EvalRecord, History, RecoveryAction, RecoveryEvent, RecoveryKind, StepRecord,
};
pub use resilient::{run_resilient, FaultTolerantModel, SoftmaxDemo, EXPLOSION_THRESHOLD};
pub use sweep::{Sweep, SweepRow};
pub use trainer::{RunResult, Trainer};
