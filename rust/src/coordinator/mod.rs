//! L3 coordinator: experiment configuration, the training loop, metric
//! collection, checkpointing, sweep scheduling, and the per-table/figure
//! reproduction harnesses (`repro`).

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod report;
pub mod repro;
pub mod sweep;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use config::{default_base_lr, parse_schedule, LrSchedule, RunConfig, DEFAULT_PREFETCH_DEPTH};
pub use metrics::{EvalRecord, History, StepRecord};
pub use sweep::{Sweep, SweepRow};
pub use trainer::{RunResult, Trainer};
