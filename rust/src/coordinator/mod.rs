//! L3 coordinator: experiment configuration, the training loop, metric
//! collection, checkpointing, sweep scheduling, and the per-table/figure
//! reproduction harnesses (`repro`).
//!
//! Two training drivers sit on top of the shared [`RunConfig`] /
//! [`History`] / [`run_resilient`] machinery: [`trainer::Trainer`]
//! executes AOT-compiled XLA artifacts, while
//! [`crate::nn::Trainer`](crate::nn::trainer::Trainer) runs the native
//! pure-rust forward/backward path (no artifacts, no Python). Both drive
//! the same watchdog, checkpoint, and CSV/JSON artifact plumbing, so
//! their curves land in identical formats.

pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod report;
pub mod repro;
pub mod resilient;
pub mod sweep;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointStore, CkptError};
pub use config::{default_base_lr, parse_schedule, LrSchedule, RunConfig, DEFAULT_PREFETCH_DEPTH};
pub use metrics::{
    EvalRecord, History, RecoveryAction, RecoveryEvent, RecoveryKind, StepRecord,
};
pub use resilient::{run_resilient, FaultTolerantModel, SoftmaxDemo, EXPLOSION_THRESHOLD};
pub use sweep::{Sweep, SweepRow};
pub use trainer::{RunResult, Trainer};
