//! Report generator: assemble the recorded `results/*.json` files into the
//! markdown tables EXPERIMENTS.md records — the single source of truth for
//! "paper vs measured". Run via `hbfp report [--results DIR]`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One recorded run, loaded back from its summary JSON.
#[derive(Debug, Clone)]
pub struct Recorded {
    pub combo: String,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    pub final_error: f32,
    pub final_loss: f32,
    pub diverged: bool,
    pub steps_per_sec: f64,
}

impl Recorded {
    pub fn perplexity(&self) -> f32 {
        self.final_loss.exp()
    }

    pub fn error_pct(&self) -> String {
        if self.diverged {
            "diverged".into()
        } else {
            format!("{:.2}%", self.final_error * 100.0)
        }
    }
}

/// Load every `*_s*_n*.json` result in a directory, newest per combo key.
pub fn load_results(dir: &Path) -> Result<Vec<Recorded>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if !name.ends_with(".json") || !name.contains("_s") || !name.contains("_n") {
            continue;
        }
        let text = std::fs::read_to_string(&path)?;
        let Ok(j) = Json::parse(&text) else { continue };
        let Some(cfg) = j.get("config") else { continue };
        let (Some(combo), Some(steps), Some(seed)) = (
            cfg.get("combo").and_then(|v| v.as_str()),
            cfg.get("steps").and_then(|v| v.as_usize()),
            cfg.get("seed").and_then(|v| v.as_i64()),
        ) else {
            continue;
        };
        out.push(Recorded {
            combo: combo.to_string(),
            steps,
            seed: seed as u64,
            eval_every: cfg.get("eval_every").and_then(|v| v.as_usize()).unwrap_or(0),
            final_error: j.get("final_error").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) as f32,
            final_loss: j.get("final_loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) as f32,
            diverged: j.get("diverged").and_then(|v| v.as_bool()).unwrap_or(false),
            steps_per_sec: j.get("steps_per_sec").and_then(|v| v.as_f64()).unwrap_or(0.0),
        });
    }
    out.sort_by(|a, b| a.combo.cmp(&b.combo).then(a.steps.cmp(&b.steps)));
    Ok(out)
}

fn index(rows: &[Recorded]) -> BTreeMap<String, &Recorded> {
    // last write wins: prefer the longest run per combo
    let mut m: BTreeMap<String, &Recorded> = BTreeMap::new();
    for r in rows {
        let e = m.entry(r.combo.clone()).or_insert(r);
        if r.steps >= e.steps {
            *e = r;
        }
    }
    m
}

/// Render the full markdown report. Sections mirror EXPERIMENTS.md.
pub fn render_markdown(rows: &[Recorded]) -> String {
    let ix = index(rows);
    let get = |combo: &str| ix.get(combo).copied();
    let cell = |combo: &str| get(combo).map(|r| r.error_pct()).unwrap_or_else(|| "—".into());
    let mut out = String::new();
    let push = |out: &mut String, s: &str| {
        out.push_str(s);
        out.push('\n');
    };

    push(&mut out, "## Table 1 — narrow-FP formats (resnet_mini / cifar10like)\n");
    push(&mut out, "| format | paper (ResNet-20/CIFAR-10) | ours |");
    push(&mut out, "|---|---|---|");
    for (cfg, label, paper) in [
        ("fp_m2_e8", "m=2, e=8", "N/A (diverges)"),
        ("fp_m4_e8", "m=4, e=8", "9.77%"),
        ("fp_m8_e8", "m=8, e=8", "8.05%"),
        ("fp32", "m=24, e=8 (fp32)", "8.42%"),
        ("fp_m24_e6", "m=24, e=6", "14.67%"),
        ("fp_m24_e2", "m=24, e=2", "N/A (diverges)"),
    ] {
        push(
            &mut out,
            &format!("| {label} | {paper} | {} |", cell(&format!("resnet_mini-cifar10like-{cfg}"))),
        );
    }

    push(&mut out, "\n## Table 2 — image classification (val error; gap = max |hbfp - fp32|)\n");
    push(&mut out, "| model-dataset | fp32 | hbfp8_16 | hbfp12_16 | max gap |");
    push(&mut out, "|---|---|---|---|---|");
    for (m, d) in [
        ("resnet_mini", "cifar100like"),
        ("wrn_mini", "cifar100like"),
        ("densenet_mini", "cifar100like"),
        ("resnet_mini", "svhnlike"),
        ("wrn_mini", "svhnlike"),
        ("densenet_mini", "svhnlike"),
        ("resnet_mini", "imagenetlike"),
    ] {
        let e = |c: &str| get(&format!("{m}-{d}-{c}")).map(|r| r.final_error);
        let gap = match (e("fp32"), e("hbfp8_16_t24"), e("hbfp12_16_t24")) {
            (Some(f), Some(h8), Some(h12)) => {
                format!("{:+.2}pp", ((h8 - f).abs().max((h12 - f).abs())) * 100.0)
            }
            _ => "—".into(),
        };
        push(
            &mut out,
            &format!(
                "| {m}-{d} | {} | {} | {} | {gap} |",
                cell(&format!("{m}-{d}-fp32")),
                cell(&format!("{m}-{d}-hbfp8_16_t24")),
                cell(&format!("{m}-{d}-hbfp12_16_t24")),
            ),
        );
    }

    push(&mut out, "\n## Table 3 — LSTM LM perplexity\n");
    push(&mut out, "| config | paper (PTB) | ours (markov corpus) |");
    push(&mut out, "|---|---|---|");
    for (cfg, paper) in [("fp32", "61.31"), ("hbfp8_16_t24", "61.86"), ("hbfp12_16_t24", "61.35")] {
        let ours = get(&format!("lstm-ptblike-{cfg}"))
            .map(|r| format!("{:.3}", r.perplexity()))
            .unwrap_or("—".into());
        push(&mut out, &format!("| {cfg} | {paper} | {ours} |"));
    }

    push(&mut out, "\n## §6 mantissa sweep (wrn_mini / cifar100like; gap vs fp32)\n");
    push(&mut out, "| config | val error | gap |");
    push(&mut out, "|---|---|---|");
    let base = get("wrn_mini-cifar100like-fp32").map(|r| r.final_error);
    for cfg in [
        "fp32",
        "hbfp4_4_t24",
        "hbfp4_16_t24",
        "hbfp8_8_t24",
        "hbfp8_16_t24",
        "hbfp12_12_t24",
        "hbfp12_16_t24",
        "hbfp16_16_t24",
    ] {
        let combo = format!("wrn_mini-cifar100like-{cfg}");
        let gap = match (base, get(&combo)) {
            (Some(b), Some(r)) if !r.diverged => format!("{:+.2}pp", (r.final_error - b) * 100.0),
            _ => "—".into(),
        };
        push(&mut out, &format!("| {cfg} | {} | {gap} |", cell(&combo)));
    }

    push(&mut out, "\n## §6 tile sweep (wrn_mini / cifar100like, hbfp8_16)\n");
    push(&mut out, "| tile | val error | gap |");
    push(&mut out, "|---|---|---|");
    for (cfg, label) in [
        ("fp32", "fp32"),
        ("hbfp8_16_tnone", "whole tensor"),
        ("hbfp8_16_t8", "8x8"),
        ("hbfp8_16_t24", "24x24"),
        ("hbfp8_16_t64", "64x64"),
    ] {
        let combo = format!("wrn_mini-cifar100like-{cfg}");
        let gap = match (base, get(&combo)) {
            (Some(b), Some(r)) if !r.diverged => format!("{:+.2}pp", (r.final_error - b) * 100.0),
            _ => "—".into(),
        };
        push(&mut out, &format!("| {label} | {} | {gap} |", cell(&combo)));
    }

    push(&mut out, "\n## Throughput of recorded runs (steps/sec, PJRT CPU)\n");
    push(&mut out, "| combo | steps/s |");
    push(&mut out, "|---|---|");
    for r in index(rows).values() {
        push(&mut out, &format!("| {} | {:.1} |", r.combo, r.steps_per_sec));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_result(dir: &Path, combo: &str, err: f64, loss: f64) {
        let j = format!(
            r#"{{"config": {{"combo": "{combo}", "steps": 300, "seed": 0, "eval_every": 0}},
                "final_error": {err}, "final_loss": {loss}, "diverged": false,
                "steps_per_sec": 5.0}}"#
        );
        std::fs::write(dir.join(format!("{combo}_s0_n300.json")), j).unwrap();
    }

    #[test]
    fn load_and_render() {
        let dir = std::env::temp_dir().join("hbfp_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        write_result(&dir, "resnet_mini-cifar100like-fp32", 0.45, 1.5);
        write_result(&dir, "resnet_mini-cifar100like-hbfp8_16_t24", 0.46, 1.52);
        write_result(&dir, "lstm-ptblike-fp32", 0.6, 1.9);
        std::fs::write(dir.join("garbage.json"), "not json").unwrap();
        let rows = load_results(&dir).unwrap();
        assert_eq!(rows.len(), 3);
        let md = render_markdown(&rows);
        assert!(md.contains("| resnet_mini-cifar100like | 45.00% | 46.00% | — |"), "{md}");
        assert!(md.contains("6.686") || md.contains("6.68"), "lstm ppl exp(1.9): {md}");
    }

    #[test]
    fn prefers_longest_run() {
        let dir = std::env::temp_dir().join("hbfp_report_test2");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("m-d-fp32_s0_n100.json"),
            r#"{"config": {"combo": "m-d-fp32", "steps": 100, "seed": 0}, "final_error": 0.5, "final_loss": 1.0, "diverged": false, "steps_per_sec": 1.0}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("m-d-fp32_s0_n300.json"),
            r#"{"config": {"combo": "m-d-fp32", "steps": 300, "seed": 0}, "final_error": 0.3, "final_loss": 0.8, "diverged": false, "steps_per_sec": 1.0}"#,
        )
        .unwrap();
        let rows = load_results(&dir).unwrap();
        let ix = index(&rows);
        assert_eq!(ix["m-d-fp32"].final_error, 0.3);
    }
}
