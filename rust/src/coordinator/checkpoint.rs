//! Crash-safe checkpointing: serialize the flat device state (param +
//! momentum + BN leaves) to a single binary file with a JSON header,
//! restore it into a fresh run. Format v2:
//!
//! ```text
//! [u32 magic "HBFC"] [u32 version = 2] [u32 header_len]
//! [header JSON bytes] [raw f32/i32 data...] [u32 crc32]
//! ```
//!
//! The header pins combo, step, and per-leaf (name, dtype, shape) so a
//! checkpoint cannot be silently restored into a mismatched artifact.
//! The trailing CRC-32 (IEEE, over every byte before the trailer) makes
//! torn writes and bit rot detectable: [`Checkpoint::load`] verifies it
//! before trusting anything past the magic.
//!
//! Durability: [`Checkpoint::save`] writes a temp file in the target
//! directory, `fsync`s it, then atomically renames it over the
//! destination (and fsyncs the directory), so a crash mid-save never
//! leaves a half-written file under the checkpoint's name.
//! [`CheckpointStore`] keeps a `latest`/`prev` pair and restores from the
//! newest file that validates, so even a corrupted latest (e.g. the
//! `ckpt-truncate` fault site firing between fsync and rename) rolls back
//! one save instead of killing the run.
//!
//! Errors are typed ([`CkptError`]): the trainer distinguishes "corrupt
//! file" (fall back to the previous checkpoint) from "wrong artifact"
//! (a real configuration error that must not be skipped).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::runtime::{DType, HostTensor, TensorSpec};
use crate::util::crc::{crc32, Crc32};
use crate::util::fault::{self, FaultSite};
use crate::util::json::Json;

const MAGIC: u32 = 0x4842_4643; // "HBFC"
/// Current on-disk format version. v1 (no version field, no CRC) is
/// rejected with [`CkptError::Version`] — its second word is a header
/// length, which never collides with small version numbers in practice.
pub const VERSION: u32 = 2;

/// Typed checkpoint errors, so callers can tell recoverable corruption
/// (try the previous checkpoint) from configuration errors (don't).
#[derive(Debug)]
pub enum CkptError {
    /// Filesystem-level failure (open/read/write/rename/fsync).
    Io { path: PathBuf, source: std::io::Error },
    /// The file exists but fails validation: bad magic, truncated, CRC
    /// mismatch, unparseable header, payload size off. Recoverable by
    /// falling back to an older checkpoint.
    Corrupt { path: PathBuf, why: String },
    /// The file's format version is not [`VERSION`] (version skew).
    Version { path: PathBuf, found: u32 },
    /// The checkpoint is internally valid but does not match the
    /// artifact it is being restored into (wrong combo, leaf count,
    /// dtype, or shape). NOT recoverable by trying older files.
    Mismatch { why: String },
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Io { path, source } => {
                write!(f, "checkpoint io error at {path:?}: {source}")
            }
            CkptError::Corrupt { path, why } => write!(f, "corrupt checkpoint {path:?}: {why}"),
            CkptError::Version { path, found } => write!(
                f,
                "checkpoint {path:?}: unsupported format version {found} (this build reads v{VERSION})"
            ),
            CkptError::Mismatch { why } => write!(f, "checkpoint/artifact mismatch: {why}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl CkptError {
    /// True when trying an older checkpoint could still succeed (corrupt
    /// or version-skewed file), false for mismatches and IO failures that
    /// indicate a configuration problem rather than a bad file.
    pub fn is_recoverable_corruption(&self) -> bool {
        matches!(self, CkptError::Corrupt { .. } | CkptError::Version { .. })
    }
}

pub struct Checkpoint {
    pub combo: String,
    pub step: usize,
    pub leaves: Vec<HostTensor>,
}

impl Checkpoint {
    /// Encode the full v2 file image (magic through CRC trailer).
    fn encode(&self, specs: &[TensorSpec]) -> Result<Vec<u8>, CkptError> {
        if specs.len() != self.leaves.len() {
            return Err(CkptError::Mismatch {
                why: format!("{} specs vs {} leaves", specs.len(), self.leaves.len()),
            });
        }
        let header = Json::obj(vec![
            ("combo", Json::str(self.combo.clone())),
            ("step", Json::num(self.step as f64)),
            (
                "leaves",
                Json::Arr(
                    specs
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::str(s.name.clone())),
                                (
                                    "dtype",
                                    Json::str(match s.dtype {
                                        DType::F32 => "f32",
                                        DType::I32 => "i32",
                                        DType::U32 => "u32",
                                    }),
                                ),
                                (
                                    "shape",
                                    Json::Arr(
                                        s.shape.iter().map(|&d| Json::num(d as f64)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        let mut bytes = Vec::with_capacity(12 + header.len());
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u32).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for leaf in &self.leaves {
            match leaf {
                HostTensor::F32(v, _) => {
                    for x in v {
                        bytes.extend_from_slice(&x.to_le_bytes());
                    }
                }
                HostTensor::I32(v, _) => {
                    for x in v {
                        bytes.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        let crc = crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        Ok(bytes)
    }

    /// Atomically write the checkpoint: temp file in the destination
    /// directory, `fsync`, rename over `path`, `fsync` the directory.
    /// A crash at any point leaves either the old file or the new file,
    /// never a torn one (the `ckpt-truncate` / `ckpt-garble` fault sites
    /// simulate the failure this protects against).
    pub fn save(&self, path: &Path, specs: &[TensorSpec]) -> Result<(), CkptError> {
        let io = |p: &Path| {
            let p = p.to_path_buf();
            move |e: std::io::Error| CkptError::Io { path: p.clone(), source: e }
        };
        let mut bytes = self.encode(specs)?;

        // Injected media faults, applied to the image we are about to
        // install: a torn write (truncate) or bit rot (garble). Applied
        // *after* encode so the installed file really is corrupt and the
        // restore path must fall back.
        if fault::fire(FaultSite::CkptTruncate) {
            bytes.truncate(bytes.len() / 2);
        }
        if fault::fire(FaultSite::CkptGarble) {
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
        }

        let dir = path.parent().unwrap_or_else(|| Path::new(""));
        std::fs::create_dir_all(dir).map_err(io(dir))?;
        let stem = path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        let tmp = dir.join(format!(".{stem}.tmp.{}", std::process::id()));
        let write_tmp = || -> Result<(), CkptError> {
            let mut f = std::fs::File::create(&tmp).map_err(io(&tmp))?;
            f.write_all(&bytes).map_err(io(&tmp))?;
            f.sync_all().map_err(io(&tmp))?;
            std::fs::rename(&tmp, path).map_err(io(path))?;
            Ok(())
        };
        if let Err(e) = write_tmp() {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        // Make the rename itself durable. Ignore failure: some
        // filesystems refuse fsync on directories, and the data file is
        // already synced.
        let sync_dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        if let Ok(d) = std::fs::File::open(sync_dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    /// Load and fully validate a checkpoint: magic, version, CRC, header,
    /// and payload size are all checked before any leaf is constructed,
    /// so a truncated or bit-flipped file yields a typed error — never a
    /// panic, never garbage tensors.
    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        let corrupt = |why: String| CkptError::Corrupt { path: path.to_path_buf(), why };
        let bytes = std::fs::read(path)
            .map_err(|e| CkptError::Io { path: path.to_path_buf(), source: e })?;
        let word = |at: usize| -> Option<u32> {
            bytes.get(at..at + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
        };
        let magic = word(0).ok_or_else(|| corrupt("shorter than the magic".into()))?;
        if magic != MAGIC {
            return Err(corrupt("not an HBFP checkpoint (bad magic)".into()));
        }
        let version = word(4).ok_or_else(|| corrupt("truncated before version".into()))?;
        if version != VERSION {
            return Err(CkptError::Version { path: path.to_path_buf(), found: version });
        }
        let hlen = word(8).ok_or_else(|| corrupt("truncated before header length".into()))? as usize;
        let body_end = bytes.len().saturating_sub(4);
        if 12 + hlen > body_end {
            return Err(corrupt(format!(
                "truncated: header claims {hlen} bytes, file has {} before the CRC trailer",
                body_end.saturating_sub(12)
            )));
        }
        let stored_crc = word(body_end).expect("body_end is in range");
        let mut crc = Crc32::new();
        crc.update(&bytes[..body_end]);
        let computed = crc.finish();
        if computed != stored_crc {
            return Err(corrupt(format!(
                "CRC mismatch: stored {stored_crc:#010x}, computed {computed:#010x}"
            )));
        }
        let htext = std::str::from_utf8(&bytes[12..12 + hlen])
            .map_err(|e| corrupt(format!("header is not UTF-8: {e}")))?;
        let header = Json::parse(htext).map_err(|e| corrupt(format!("header JSON: {e}")))?;
        let get_str = |j: &Json, k: &str| -> Result<String, CkptError> {
            j.get(k)
                .and_then(|v| v.as_str().map(|s| s.to_string()))
                .ok_or_else(|| corrupt(format!("header missing string field `{k}`")))
        };
        let combo = get_str(&header, "combo")?;
        let step = header
            .get("step")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| corrupt("header missing numeric field `step`".into()))?;
        let leaf_hdrs = header
            .get("leaves")
            .and_then(|v| v.as_arr().map(|a| a.to_vec()))
            .ok_or_else(|| corrupt("header missing array field `leaves`".into()))?;

        let mut payload = &bytes[12 + hlen..body_end];
        let mut leaves = Vec::with_capacity(leaf_hdrs.len());
        for l in &leaf_hdrs {
            let shape: Vec<usize> = l
                .get("shape")
                .and_then(|v| v.as_arr().map(|a| a.to_vec()))
                .ok_or_else(|| corrupt("leaf missing `shape`".into()))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| corrupt("non-integer shape dim".into())))
                .collect::<Result<_, _>>()?;
            let n: usize = shape.iter().product();
            if payload.len() < n * 4 {
                return Err(corrupt(format!(
                    "payload short: leaf wants {} bytes, {} remain",
                    n * 4,
                    payload.len()
                )));
            }
            let (raw, rest) = payload.split_at(n * 4);
            payload = rest;
            let dtype = get_str(l, "dtype")?;
            let leaf = match dtype.as_str() {
                "f32" => HostTensor::F32(
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                    shape,
                ),
                "i32" => HostTensor::I32(
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                    shape,
                ),
                other => return Err(corrupt(format!("unsupported checkpoint dtype {other}"))),
            };
            leaves.push(leaf);
        }
        if !payload.is_empty() {
            return Err(corrupt(format!("{} trailing payload bytes", payload.len())));
        }
        Ok(Checkpoint { combo, step, leaves })
    }

    /// Validate against the artifact's state specs before restoring.
    /// Failures are [`CkptError::Mismatch`] — a wrong-artifact error,
    /// distinct from file corruption.
    pub fn check_against(&self, combo: &str, specs: &[TensorSpec]) -> Result<(), CkptError> {
        if self.combo != combo {
            return Err(CkptError::Mismatch {
                why: format!("checkpoint is for {:?}, not {combo:?}", self.combo),
            });
        }
        if self.leaves.len() != specs.len() {
            return Err(CkptError::Mismatch {
                why: format!(
                    "checkpoint has {} leaves, artifact expects {}",
                    self.leaves.len(),
                    specs.len()
                ),
            });
        }
        for (leaf, spec) in self.leaves.iter().zip(specs) {
            leaf.check(spec).map_err(|e| CkptError::Mismatch { why: format!("{e:#}") })?;
        }
        Ok(())
    }
}

/// A `latest`/`prev` checkpoint pair under one directory: every save
/// rotates the previous latest aside before installing the new file, and
/// restore walks newest-to-oldest taking the first file that validates.
/// One corrupted save therefore costs one checkpoint interval, not the
/// run.
pub struct CheckpointStore {
    dir: PathBuf,
    name: String,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>, name: impl Into<String>) -> CheckpointStore {
        CheckpointStore { dir: dir.into(), name: name.into() }
    }

    /// Path of the newest checkpoint (`<dir>/<name>.ckpt`).
    pub fn latest_path(&self) -> PathBuf {
        self.dir.join(format!("{}.ckpt", self.name))
    }

    /// Path of the rotated previous checkpoint (`<dir>/<name>.prev.ckpt`).
    pub fn prev_path(&self) -> PathBuf {
        self.dir.join(format!("{}.prev.ckpt", self.name))
    }

    /// Rotate `latest` to `prev` (atomic rename), then atomically write
    /// the new checkpoint as `latest`.
    pub fn save(&self, ck: &Checkpoint, specs: &[TensorSpec]) -> Result<(), CkptError> {
        let latest = self.latest_path();
        if latest.exists() {
            std::fs::rename(&latest, self.prev_path())
                .map_err(|e| CkptError::Io { path: latest.clone(), source: e })?;
        }
        ck.save(&latest, specs)
    }

    /// Restore the newest checkpoint that validates against the artifact.
    ///
    /// Corrupt / version-skewed / unreadable files are logged and skipped
    /// (falling back from `latest` to `prev`); a [`CkptError::Mismatch`]
    /// propagates immediately because a wrong-artifact checkpoint is a
    /// configuration error, not recoverable corruption. `Ok(None)` means
    /// no checkpoint exists at all (a fresh run).
    pub fn load_newest_valid(
        &self,
        combo: &str,
        specs: &[TensorSpec],
    ) -> Result<Option<(Checkpoint, PathBuf)>, CkptError> {
        for path in [self.latest_path(), self.prev_path()] {
            if !path.exists() {
                continue;
            }
            match Checkpoint::load(&path) {
                Ok(ck) => match ck.check_against(combo, specs) {
                    Ok(()) => return Ok(Some((ck, path))),
                    Err(e @ CkptError::Mismatch { .. }) => return Err(e),
                    Err(e) => {
                        log::warn!("skipping {path:?}: {e}");
                        continue;
                    }
                },
                Err(e) => {
                    log::warn!("skipping {path:?}: {e}");
                    continue;
                }
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "state/w".into(), shape: vec![2, 3], dtype: DType::F32 },
            TensorSpec { name: "state/y".into(), shape: vec![2], dtype: DType::I32 },
        ]
    }

    fn ckpt() -> Checkpoint {
        Checkpoint {
            combo: "m-d-fp32".into(),
            step: 42,
            leaves: vec![
                HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5], vec![2, 3]),
                HostTensor::I32(vec![-1, 7], vec![2]),
            ],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hbfp_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let p = tmp("roundtrip.bin");
        ckpt().save(&p, &specs()).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.combo, "m-d-fp32");
        assert_eq!(back.step, 42);
        assert_eq!(back.leaves, ckpt().leaves);
        back.check_against("m-d-fp32", &specs()).unwrap();
    }

    #[test]
    fn mismatch_is_typed() {
        let p = tmp("mismatch.bin");
        ckpt().save(&p, &specs()).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        let e = back.check_against("other", &specs()).unwrap_err();
        assert!(matches!(e, CkptError::Mismatch { .. }), "{e}");
        assert!(!e.is_recoverable_corruption());
        let mut wrong = specs();
        wrong[0].shape = vec![3, 2];
        let e = back.check_against("m-d-fp32", &wrong).unwrap_err();
        assert!(matches!(e, CkptError::Mismatch { .. }), "{e}");
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        let e = Checkpoint::load(&p).unwrap_err();
        assert!(matches!(e, CkptError::Corrupt { .. }), "{e}");
    }

    #[test]
    fn rejects_every_truncation_length() {
        let p = tmp("trunc_src.bin");
        ckpt().save(&p, &specs()).unwrap();
        let full = std::fs::read(&p).unwrap();
        let q = tmp("trunc.bin");
        for len in 0..full.len() {
            std::fs::write(&q, &full[..len]).unwrap();
            let e = Checkpoint::load(&q).unwrap_err();
            assert!(
                matches!(e, CkptError::Corrupt { .. } | CkptError::Io { .. }),
                "len {len}: {e}"
            );
        }
    }

    #[test]
    fn rejects_every_single_byte_corruption() {
        let p = tmp("garble_src.bin");
        ckpt().save(&p, &specs()).unwrap();
        let full = std::fs::read(&p).unwrap();
        let q = tmp("garble.bin");
        for at in 0..full.len() {
            let mut bad = full.clone();
            bad[at] ^= 0x01;
            std::fs::write(&q, &bad).unwrap();
            assert!(Checkpoint::load(&q).is_err(), "flip at byte {at} must be rejected");
        }
    }

    #[test]
    fn rejects_version_skew() {
        let p = tmp("ver.bin");
        ckpt().save(&p, &specs()).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Bump the version word and fix up the CRC so only the version is
        // "wrong" — the reader must still refuse it.
        bytes[4..8].copy_from_slice(&3u32.to_le_bytes());
        let body_end = bytes.len() - 4;
        let crc = crate::util::crc::crc32(&bytes[..body_end]);
        bytes[body_end..].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let e = Checkpoint::load(&p).unwrap_err();
        assert!(matches!(e, CkptError::Version { found: 3, .. }), "{e}");
        assert!(e.is_recoverable_corruption());
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_files() {
        let dir = tmp("atomic_dir");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("deep/nested/state.ckpt");
        ckpt().save(&p, &specs()).unwrap();
        Checkpoint::load(&p).unwrap();
        let entries: Vec<_> = std::fs::read_dir(p.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(entries, vec!["state.ckpt"], "no temp litter: {entries:?}");
    }

    #[test]
    fn store_rotates_and_falls_back() {
        let dir = tmp("store_dir");
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::new(&dir, "m-d-fp32");
        assert!(store.load_newest_valid("m-d-fp32", &specs()).unwrap().is_none());

        let mut a = ckpt();
        a.step = 10;
        store.save(&a, &specs()).unwrap();
        let mut b = ckpt();
        b.step = 20;
        store.save(&b, &specs()).unwrap();
        assert!(store.latest_path().exists() && store.prev_path().exists());

        let (ck, path) = store.load_newest_valid("m-d-fp32", &specs()).unwrap().unwrap();
        assert_eq!(ck.step, 20);
        assert_eq!(path, store.latest_path());

        // Corrupt latest: restore must fall back to prev (step 10).
        let mut bytes = std::fs::read(store.latest_path()).unwrap();
        let mid = bytes.len() / 2;
        bytes.truncate(mid);
        std::fs::write(store.latest_path(), &bytes).unwrap();
        let (ck, path) = store.load_newest_valid("m-d-fp32", &specs()).unwrap().unwrap();
        assert_eq!(ck.step, 10, "fell back to prev");
        assert_eq!(path, store.prev_path());

        // Wrong combo is a mismatch, not a silent skip.
        let e = store.load_newest_valid("other-combo", &specs()).unwrap_err();
        assert!(matches!(e, CkptError::Mismatch { .. }), "{e}");

        // Corrupt both: no checkpoint to restore.
        std::fs::copy(store.latest_path(), store.prev_path()).unwrap();
        assert!(store.load_newest_valid("m-d-fp32", &specs()).unwrap().is_none());
    }
}
