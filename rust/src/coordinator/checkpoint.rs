//! Checkpointing: serialize the flat device state (param + momentum + BN
//! leaves) to a single binary file with a JSON header, restore it into a
//! fresh run. Format:
//!
//! ```text
//! [u32 magic "HBFC"] [u32 header_len] [header JSON bytes] [raw f32/i32 data...]
//! ```
//!
//! The header pins combo, step, and per-leaf (name, dtype, shape) so a
//! checkpoint cannot be silently restored into a mismatched artifact.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{DType, HostTensor, TensorSpec};
use crate::util::json::Json;

const MAGIC: u32 = 0x4842_4643; // "HBFC"

pub struct Checkpoint {
    pub combo: String,
    pub step: usize,
    pub leaves: Vec<HostTensor>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path, specs: &[TensorSpec]) -> Result<()> {
        if specs.len() != self.leaves.len() {
            return Err(anyhow!("{} specs vs {} leaves", specs.len(), self.leaves.len()));
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Json::obj(vec![
            ("combo", Json::str(self.combo.clone())),
            ("step", Json::num(self.step as f64)),
            (
                "leaves",
                Json::Arr(
                    specs
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::str(s.name.clone())),
                                (
                                    "dtype",
                                    Json::str(match s.dtype {
                                        DType::F32 => "f32",
                                        DType::I32 => "i32",
                                        DType::U32 => "u32",
                                    }),
                                ),
                                (
                                    "shape",
                                    Json::Arr(s.shape.iter().map(|&d| Json::num(d as f64)).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        f.write_all(&MAGIC.to_le_bytes())?;
        f.write_all(&(header.len() as u32).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for leaf in &self.leaves {
            match leaf {
                HostTensor::F32(v, _) => {
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
                HostTensor::I32(v, _) => {
                    for x in v {
                        f.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        if u32::from_le_bytes(u32buf) != MAGIC {
            return Err(anyhow!("{path:?} is not an HBFP checkpoint"));
        }
        f.read_exact(&mut u32buf)?;
        let hlen = u32::from_le_bytes(u32buf) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let combo = header.req("combo")?.as_str().context("combo")?.to_string();
        let step = header.req("step")?.as_usize().context("step")?;
        let mut leaves = Vec::new();
        for l in header.req("leaves")?.as_arr().context("leaves")? {
            let shape: Vec<usize> = l
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect();
            let n: usize = shape.iter().product();
            let mut raw = vec![0u8; n * 4];
            f.read_exact(&mut raw)?;
            let dtype = l.req("dtype")?.as_str().context("dtype")?;
            let leaf = match dtype {
                "f32" => HostTensor::F32(
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                    shape,
                ),
                "i32" => HostTensor::I32(
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                    shape,
                ),
                _ => return Err(anyhow!("unsupported checkpoint dtype {dtype}")),
            };
            leaves.push(leaf);
        }
        Ok(Checkpoint { combo, step, leaves })
    }

    /// Validate against the artifact's state specs before restoring.
    pub fn check_against(&self, combo: &str, specs: &[TensorSpec]) -> Result<()> {
        if self.combo != combo {
            return Err(anyhow!("checkpoint is for {:?}, not {combo:?}", self.combo));
        }
        if self.leaves.len() != specs.len() {
            return Err(anyhow!(
                "checkpoint has {} leaves, artifact expects {}",
                self.leaves.len(),
                specs.len()
            ));
        }
        for (leaf, spec) in self.leaves.iter().zip(specs) {
            leaf.check(spec)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "state/w".into(), shape: vec![2, 3], dtype: DType::F32 },
            TensorSpec { name: "state/y".into(), shape: vec![2], dtype: DType::I32 },
        ]
    }

    fn ckpt() -> Checkpoint {
        Checkpoint {
            combo: "m-d-fp32".into(),
            step: 42,
            leaves: vec![
                HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5], vec![2, 3]),
                HostTensor::I32(vec![-1, 7], vec![2]),
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let p = std::env::temp_dir().join("hbfp_ckpt_test.bin");
        ckpt().save(&p, &specs()).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.combo, "m-d-fp32");
        assert_eq!(back.step, 42);
        assert_eq!(back.leaves, ckpt().leaves);
        back.check_against("m-d-fp32", &specs()).unwrap();
    }

    #[test]
    fn mismatch_detected() {
        let p = std::env::temp_dir().join("hbfp_ckpt_test2.bin");
        ckpt().save(&p, &specs()).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert!(back.check_against("other", &specs()).is_err());
        let mut wrong = specs();
        wrong[0].shape = vec![3, 2];
        assert!(back.check_against("m-d-fp32", &wrong).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = std::env::temp_dir().join("hbfp_ckpt_garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }
}
