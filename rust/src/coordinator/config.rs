//! Experiment configuration: what to train, for how long, with which
//! learning-rate schedule. Parsed from CLI options and/or JSON files, and
//! embedded in every result file so runs are self-describing.

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Learning-rate schedule. The paper trains with the original papers'
/// hyperparameters (step decay for the CNNs, constant-ish for the LSTM);
/// cosine is provided for the ablation harnesses.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// lr = base * gamma^(number of milestones passed)
    StepDecay { base: f32, gamma: f32, milestones: Vec<usize> },
    /// half-cosine from base to floor over total steps
    Cosine { base: f32, floor: f32, total: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::StepDecay { base, gamma, milestones } => {
                let k = milestones.iter().filter(|&&m| step >= m).count() as i32;
                base * gamma.powi(k)
            }
            LrSchedule::Cosine { base, floor, total } => {
                let t = (step as f32 / (*total).max(1) as f32).min(1.0);
                floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// Default schedule for a run of `steps`: step decay at 50% and 75%,
    /// the standard ResNet recipe scaled to the run length.
    pub fn default_for(steps: usize, base: f32) -> LrSchedule {
        LrSchedule::StepDecay { base, gamma: 0.1, milestones: vec![steps / 2, steps * 3 / 4] }
    }

    pub fn to_json(&self) -> Json {
        match self {
            LrSchedule::Constant { lr } => {
                Json::obj(vec![("kind", Json::str("constant")), ("lr", Json::num(*lr))])
            }
            LrSchedule::StepDecay { base, gamma, milestones } => Json::obj(vec![
                ("kind", Json::str("step")),
                ("base", Json::num(*base)),
                ("gamma", Json::num(*gamma)),
                (
                    "milestones",
                    Json::Arr(milestones.iter().map(|&m| Json::num(m as f64)).collect()),
                ),
            ]),
            LrSchedule::Cosine { base, floor, total } => Json::obj(vec![
                ("kind", Json::str("cosine")),
                ("base", Json::num(*base)),
                ("floor", Json::num(*floor)),
                ("total", Json::num(*total as f64)),
            ]),
        }
    }
}

/// One training run of one combo.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// `"{model}-{dataset}-{config}"`, must exist in the manifest.
    pub combo: String,
    pub steps: usize,
    pub seed: u64,
    pub lr: LrSchedule,
    /// Evaluate every N steps (and always at the end). 0 = only at end.
    pub eval_every: usize,
    /// Log train metrics every N steps.
    pub log_every: usize,
    /// Optional checkpoint directory.
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Host-side input conversion: quantize each incoming batch through a
    /// BFP round-trip before upload — the paper's FP→BFP converter at the
    /// accelerator boundary, modeled on the host with the parallel
    /// quantizer. `(mantissa_bits, tile_edge)`; `None` = fp32 inputs.
    pub input_bfp: Option<(u32, usize)>,
    /// Batches the prefetcher keeps in flight ahead of the trainer
    /// (`--prefetch-depth`; bounded-channel backpressure). Clamped to at
    /// least 1.
    pub prefetch_depth: usize,
    /// Write a crash-safe checkpoint every N steps (0 = only the final
    /// one, matching pre-fault-tolerance behaviour). Requires
    /// `checkpoint_dir`.
    pub checkpoint_every: usize,
    /// Watchdog budget: how many rollback-and-recover interventions the
    /// trainer attempts before giving up with an error. 0 disables the
    /// watchdog (a non-finite loss then just runs to completion and is
    /// reported by `History::diverged`).
    pub max_recoveries: usize,
}

/// Default prefetch depth: one batch being assembled + one ready.
pub const DEFAULT_PREFETCH_DEPTH: usize = 2;

impl RunConfig {
    pub fn new(combo: &str, steps: usize) -> RunConfig {
        RunConfig {
            combo: combo.to_string(),
            steps,
            seed: 0,
            lr: LrSchedule::default_for(steps, 0.05),
            eval_every: 0,
            log_every: 10,
            checkpoint_dir: None,
            input_bfp: None,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            checkpoint_every: 0,
            max_recoveries: 0,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_lr(mut self, lr: LrSchedule) -> Self {
        self.lr = lr;
        self
    }

    pub fn with_eval_every(mut self, n: usize) -> Self {
        self.eval_every = n;
        self
    }

    pub fn with_input_bfp(mut self, mantissa_bits: u32, tile_edge: usize) -> Self {
        self.input_bfp = Some((mantissa_bits, tile_edge));
        self
    }

    pub fn with_prefetch_depth(mut self, depth: usize) -> Self {
        self.prefetch_depth = depth.max(1);
        self
    }

    pub fn with_checkpoint_every(mut self, n: usize) -> Self {
        self.checkpoint_every = n;
        self
    }

    pub fn with_max_recoveries(mut self, n: usize) -> Self {
        self.max_recoveries = n;
        self
    }

    /// Parse the model name back out of the combo.
    pub fn model(&self) -> &str {
        self.combo.split('-').next().unwrap_or("")
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("combo", Json::str(self.combo.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", self.lr.to_json()),
            ("eval_every", Json::num(self.eval_every as f64)),
            (
                "input_bfp",
                match self.input_bfp {
                    Some((m, t)) => Json::str(format!("m{m}_t{t}")),
                    None => Json::Null,
                },
            ),
            ("prefetch_depth", Json::num(self.prefetch_depth as f64)),
            ("checkpoint_every", Json::num(self.checkpoint_every as f64)),
            ("max_recoveries", Json::num(self.max_recoveries as f64)),
        ])
    }
}

/// Base learning rate per model family — the "original hyperparameters"
/// rule (§5.2) scaled to the mini models (tuned on fp32 only, then reused
/// verbatim for every numeric config, exactly like the paper).
pub fn default_base_lr(model: &str) -> f32 {
    match model {
        "lstm" => 0.5,
        "mlp" => 0.1,
        _ => 0.05, // conv nets
    }
}

pub fn parse_schedule(s: &str, steps: usize) -> Result<LrSchedule> {
    // forms: "0.05" | "step:0.05" | "const:0.1" | "cosine:0.05"
    if let Ok(lr) = s.parse::<f32>() {
        return Ok(LrSchedule::default_for(steps, lr));
    }
    let (kind, val) = s.split_once(':').ok_or_else(|| anyhow!("bad schedule {s:?}"))?;
    let base: f32 = val.parse().map_err(|_| anyhow!("bad lr in {s:?}"))?;
    match kind {
        "const" => Ok(LrSchedule::Constant { lr: base }),
        "step" => Ok(LrSchedule::default_for(steps, base)),
        "cosine" => Ok(LrSchedule::Cosine { base, floor: base * 0.01, total: steps }),
        _ => Err(anyhow!("unknown schedule kind {kind:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay() {
        let s = LrSchedule::StepDecay { base: 0.1, gamma: 0.1, milestones: vec![100, 200] };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(99), 0.1);
        assert!((s.at(100) - 0.01).abs() < 1e-9);
        assert!((s.at(250) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn cosine_endpoints() {
        let s = LrSchedule::Cosine { base: 1.0, floor: 0.0, total: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!(s.at(100) < 1e-6);
        assert!((s.at(50) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn parse_forms() {
        assert!(matches!(parse_schedule("0.05", 100).unwrap(), LrSchedule::StepDecay { .. }));
        assert!(matches!(parse_schedule("const:0.1", 100).unwrap(), LrSchedule::Constant { .. }));
        assert!(matches!(parse_schedule("cosine:0.1", 100).unwrap(), LrSchedule::Cosine { .. }));
        assert!(parse_schedule("bogus", 100).is_err());
        assert!(parse_schedule("step:x", 100).is_err());
    }

    #[test]
    fn config_json_roundtrippable() {
        let c = RunConfig::new("m-d-fp32", 200).with_seed(7);
        let j = c.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("combo").unwrap().as_str(), Some("m-d-fp32"));
        assert_eq!(parsed.get("steps").unwrap().as_usize(), Some(200));
        assert_eq!(parsed.get("input_bfp"), Some(&Json::Null));
    }

    #[test]
    fn input_bfp_builder_and_json() {
        let c = RunConfig::new("m-d-hbfp8_16_t24", 10).with_input_bfp(8, 24);
        assert_eq!(c.input_bfp, Some((8, 24)));
        let parsed = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("input_bfp").unwrap().as_str(), Some("m8_t24"));
    }

    #[test]
    fn prefetch_depth_default_builder_and_clamp() {
        let c = RunConfig::new("m-d-fp32", 10);
        assert_eq!(c.prefetch_depth, DEFAULT_PREFETCH_DEPTH);
        assert_eq!(c.with_prefetch_depth(5).prefetch_depth, 5);
        let clamped = RunConfig::new("m-d-fp32", 10).with_prefetch_depth(0);
        assert_eq!(clamped.prefetch_depth, 1, "depth 0 (rendezvous) would defeat prefetching");
        let parsed =
            Json::parse(&RunConfig::new("m-d-fp32", 10).to_json().to_string()).unwrap();
        assert_eq!(parsed.get("prefetch_depth").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn fault_tolerance_knobs_default_off() {
        let c = RunConfig::new("m-d-fp32", 10);
        assert_eq!((c.checkpoint_every, c.max_recoveries), (0, 0));
        let c = c.with_checkpoint_every(25).with_max_recoveries(3);
        let parsed = Json::parse(&c.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("checkpoint_every").unwrap().as_usize(), Some(25));
        assert_eq!(parsed.get("max_recoveries").unwrap().as_usize(), Some(3));
    }
}
