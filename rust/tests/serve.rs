//! Serving front-end integration: the deterministic overload soak
//! (admission ladder, deadlines, degradation, contained faults — replayed
//! twice and compared bit-for-bit), the two-tenant flood soak (fair-share
//! scheduling under a 10x flooding neighbour), targeted deadline-expiry
//! tests for the `slow-worker` and `slow-request` fault sites, contained
//! worker-panic retry/split-fallback, circuit-breaker
//! quarantine/recovery, hot-reload rollback under `reload-garble`,
//! drain-during-burst conservation, and the environment-fault soaks the
//! CI fault-injection and chaos-lifecycle matrices drive.
//!
//! Injector discipline (same as `fault_tolerance.rs`): every test either
//! `install`s an explicit injector — which serializes it on the harness's
//! install lock and shields it from `HBFP_FAULT` and from its neighbors —
//! or holds `fault::exclusive()` to run *under* the environment's
//! injector.

use std::collections::HashMap;
use std::sync::Arc;

use hbfp::bfp::{bfp_matmul_naive, BfpContext, Isa, Rounding, TileSize};
use hbfp::serve::{
    BatchReport, BreakerConfig, BreakerState, Completion, ExpiredAt, InferenceServer, Lifecycle,
    ManualClock, Outcome, PumpReport, Rejected, ReloadError, Response, ServeConfig, Submission,
    SystemClock,
};
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};

fn weights(k: usize, n: usize) -> Vec<f32> {
    (0..k * n).map(|i| ((i as f32) * 0.173).sin() * 0.5).collect()
}

fn input(k: usize, salt: u64) -> Vec<f32> {
    (0..k).map(|i| ((i as f32) * 0.31 + salt as f32 * 0.77).cos()).collect()
}

/// Replay every served response against the naive BFP reference at the
/// width and batch grouping the server reported for it. Whole batches are
/// quantized as one `m x k` operand; split-fallback batches quantize each
/// row independently (that is what the server executed).
fn verify_served_against_naive(
    srv: &InferenceServer,
    inputs: &HashMap<u64, Vec<f32>>,
    batches: &[BatchReport],
    served: &HashMap<u64, Response>,
) {
    let ctx = srv.context();
    let mut checked = 0usize;
    for b in batches {
        let model = srv.model(b.model).unwrap();
        let (k, n) = (model.k(), model.n());
        let wb = model.weights_at(b.bits);
        if b.ids.is_empty() {
            continue;
        }
        if b.split_fallback {
            for id in &b.ids {
                let Some(resp) = served.get(id) else { continue };
                let qa = ctx
                    .quantize(&inputs[id], 1, k, b.bits, &mut Rounding::NearestEven)
                    .unwrap();
                let want = bfp_matmul_naive(&qa, wb).unwrap();
                assert_eq!(resp.output, want, "split row {id} diverged from naive");
                assert_eq!(resp.served_bits, b.bits);
                checked += 1;
            }
        } else {
            let m = b.ids.len();
            let mut flat = Vec::with_capacity(m * k);
            for id in &b.ids {
                flat.extend_from_slice(&inputs[id]);
            }
            let qa = ctx.quantize(&flat, m, k, b.bits, &mut Rounding::NearestEven).unwrap();
            let want = bfp_matmul_naive(&qa, wb).unwrap();
            for (i, id) in b.ids.iter().enumerate() {
                let Some(resp) = served.get(id) else { continue };
                assert_eq!(
                    resp.output,
                    want[i * n..(i + 1) * n].to_vec(),
                    "batched row {id} diverged from naive"
                );
                assert_eq!(resp.served_bits, b.bits);
                assert_eq!(resp.degraded, b.degraded);
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "verification must cover at least one served response");
}

fn served_map(completions: &[Completion]) -> HashMap<u64, Response> {
    completions
        .iter()
        .filter_map(|c| match &c.outcome {
            Outcome::Served(r) => Some((c.id, r.clone())),
            _ => None,
        })
        .collect()
}

fn collect_batches(reports: &[PumpReport]) -> Vec<BatchReport> {
    reports.iter().filter_map(|r| r.batch.clone()).collect()
}

// ---------------------------------------------------------------------
// The deterministic overload soak (the acceptance scenario)
// ---------------------------------------------------------------------

/// Mirrors the CI overload-soak leg's HBFP_FAULT spec. Installed
/// explicitly so the test is the same everywhere.
fn soak_specs() -> Vec<FaultSpec> {
    vec![
        FaultSpec { site: FaultSite::WorkerPanic, rate: 0.35, seed: 11 },
        FaultSpec { site: FaultSite::SlowWorker, rate: 0.5, seed: 11 },
        FaultSpec { site: FaultSite::NanActivation, rate: 0.05, seed: 11 },
        FaultSpec { site: FaultSite::SlowRequest, rate: 0.25, seed: 11 },
    ]
}

fn soak_cfg() -> ServeConfig {
    ServeConfig {
        queue_capacity: 32,
        elevated_depth: 8,
        degrade_depth: 12,
        shed_depth: 24,
        max_batch_rows: 16,
        // quantum == batch cap: single-tenant batching identical to plain
        // head-of-line coalescing, so the PR-7 soak schedule is preserved
        drr_quantum_rows: 16,
        full_bits: 16,
        degraded_bits: 8,
        default_deadline_ticks: 50_000,
        est_ticks_per_row: 200,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        max_gemm_retries: 2,
        breaker: BreakerConfig::default(),
    }
}

struct SoakRun {
    srv: InferenceServer,
    metrics_json: String,
    completions: Vec<Completion>,
    batches: Vec<BatchReport>,
    inputs: HashMap<u64, Vec<f32>>,
    submitted: u64,
}

fn soak_request(
    srv: &mut InferenceServer,
    model: usize,
    i: u64,
    inputs: &mut HashMap<u64, Vec<f32>>,
) {
    let mut x = input(256, i);
    if i % 13 == 12 {
        // a poisoned client payload rides along every 13th request
        x[2] = f32::NAN;
    }
    // every 7th request carries a tight deadline (Overloaded fodder once
    // a backlog exists), every 7k+3rd a mid deadline, the rest default
    let deadline = match i % 7 {
        0 => Some(300),
        3 => Some(6_000),
        _ => None,
    };
    if let Submission::Admitted { id, .. } = srv.submit(model, x.clone(), deadline).unwrap() {
        inputs.insert(id, x);
    }
}

fn run_soak_once() -> SoakRun {
    // Fixed tile/ISA/threads so the lane layout — and therefore the fault
    // probe schedule — does not depend on the host's vector unit.
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(soak_cfg(), ctx, clock.clone());
    // Model load runs shielded: the soak injects faults into serving, not
    // into residency building (whose pool dispatches are uncontained).
    let quiet = fault::install(FaultInjector::none());
    let model = srv.register_model("soak-256", &weights(256, 256), 256, 256).unwrap();
    drop(quiet);

    // A fresh injector per run resets the probe counters, which is what
    // makes the replay exact.
    let _g = fault::install(FaultInjector::from_specs(&soak_specs()));

    let mut inputs = HashMap::new();
    let mut submitted = 0u64;
    let mut reports = Vec::new();

    // Phase A: a 33-request burst with no pump — climbs the whole ladder
    // (nominal -> elevated -> degraded -> shedding) at twice the
    // admission capacity the shed watermark allows.
    for i in 0..33u64 {
        soak_request(&mut srv, model, i, &mut inputs);
        submitted += 1;
    }

    // Phase B: sustained 2x overload — 6 new requests per pump while each
    // pump retires at most 16 rows from a 24-deep backlog.
    for wave in 0..12u64 {
        for j in 0..6u64 {
            soak_request(&mut srv, model, 33 + wave * 6 + j, &mut inputs);
            submitted += 1;
        }
        reports.push(srv.pump().unwrap());
    }

    // Phase C: drain.
    reports.extend(srv.run_until_idle().unwrap());

    // Coda: one feasible-at-admission request that dies in the queue —
    // the deterministic dequeue-expiry case.
    let sub = srv.submit(model, input(256, 9_999), Some(300)).unwrap();
    assert!(sub.is_admitted(), "empty queue must admit a 300-tick deadline");
    if let Submission::Admitted { id, .. } = sub {
        inputs.insert(id, input(256, 9_999));
    }
    submitted += 1;
    clock.advance(400);
    reports.extend(srv.run_until_idle().unwrap());

    let completions = srv.drain_completions();
    let metrics_json = srv.metrics_json().to_string();
    let batches = collect_batches(&reports);
    SoakRun { srv, metrics_json, completions, batches, inputs, submitted }
}

#[test]
fn overload_soak_is_deterministic_and_serves_bit_identical() {
    let r1 = run_soak_once();
    let r2 = run_soak_once();

    // Replay: identical metrics (counters, histogram, plan cache) and
    // identical per-request outcomes including every output bit.
    assert_eq!(r1.metrics_json, r2.metrics_json, "soak metrics must replay identically");
    assert_eq!(r1.completions, r2.completions, "soak outcomes must replay identically");

    let m = r1.srv.metrics();

    // Conservation: every submission is accounted for exactly once.
    assert_eq!(r1.submitted, m.admitted + m.rejected_total());
    assert_eq!(m.admitted as usize, r1.inputs.len());
    assert_eq!(
        m.admitted,
        m.completed + m.expired_at_dequeue + m.expired_at_completion + m.failed,
        "admitted requests must all terminate: {m:?}"
    );
    assert_eq!(r1.completions.len() as u64, m.admitted);
    assert_eq!(r1.srv.queue_depth(), 0);

    // The ladder actually engaged under 2x load.
    assert!(m.rejected_shedding > 0, "shed watermark never hit: {m:?}");
    assert!(m.rejected_overloaded > 0, "deadline feasibility screen never hit: {m:?}");
    assert!(m.degraded_served > 0, "precision degradation never engaged: {m:?}");
    assert!(m.expired_at_dequeue > 0, "no dequeue expiry: {m:?}");
    assert!(m.expired_at_completion > 0, "no completion expiry: {m:?}");
    assert!(m.failed > 0, "poisoned payloads must fail individually: {m:?}");

    // Deadline SLO: the histogram only holds served requests, and no
    // request was admitted with more than the 50k-tick default.
    assert_eq!(m.latency.count(), m.completed);
    assert!(m.latency.p99() <= 50_000, "p99 {} above deadline ceiling", m.latency.p99());
    assert!(m.latency.p50() <= m.latency.p99());

    // With multiple pool lanes the worker-panic site must have been
    // contained (never escaped: the run finished and drained).
    if hbfp::util::worker_threads() >= 2 {
        assert!(m.panics_contained > 0, "worker-panic armed but never contained: {m:?}");
    }

    // Every served response is bit-identical to the naive reference at
    // its served width and batch grouping.
    let served = served_map(&r1.completions);
    assert_eq!(served.len() as u64, m.completed);
    verify_served_against_naive(&r1.srv, &r1.inputs, &r1.batches, &served);

    // Degraded responses are flagged and narrow.
    let degraded: Vec<&Response> = served.values().filter(|r| r.degraded).collect();
    assert_eq!(degraded.len() as u64, m.degraded_served);
    assert!(degraded.iter().all(|r| r.served_bits == 8));
}

// ---------------------------------------------------------------------
// Environment-fault soak (the CI fault-injection matrix target)
// ---------------------------------------------------------------------

/// Runs *under* `HBFP_FAULT` (whatever the environment armed, if
/// anything) and checks the robustness invariants only: the queue drains,
/// nothing escapes, accounting conserves, and everything served is still
/// bit-identical to naive. Single-lane context so an env worker-panic
/// cannot unwind model registration, which runs outside the serve loop's
/// containment.
#[test]
fn soak_survives_environment_faults() {
    let _env = fault::exclusive();
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let cfg = ServeConfig {
        queue_capacity: 16,
        elevated_depth: 4,
        degrade_depth: 6,
        shed_depth: 12,
        max_batch_rows: 8,
        est_ticks_per_row: 150,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        default_deadline_ticks: 40_000,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("env-64", &weights(64, 64), 64, 64).unwrap();

    let mut inputs = HashMap::new();
    let mut submitted = 0u64;
    let mut reports = Vec::new();
    for i in 0..60u64 {
        let x = input(64, i);
        let deadline = if i % 9 == 4 { Some(1_200) } else { None };
        if let Submission::Admitted { id, .. } = srv.submit(model, x.clone(), deadline).unwrap()
        {
            inputs.insert(id, x);
        }
        submitted += 1;
        if i % 4 == 3 {
            reports.push(srv.pump().unwrap());
        }
    }
    reports.extend(srv.run_until_idle().unwrap());

    let completions = srv.drain_completions();
    let m = srv.metrics();
    assert_eq!(srv.queue_depth(), 0, "queue must drain under env faults");
    assert_eq!(submitted, m.admitted + m.rejected_total());
    assert_eq!(
        m.admitted,
        m.completed + m.expired_at_dequeue + m.expired_at_completion + m.failed
    );
    assert_eq!(completions.len() as u64, m.admitted);
    assert!(m.completed > 0, "env faults must not starve service: {m:?}");

    let served = served_map(&completions);
    verify_served_against_naive(&srv, &inputs, &collect_batches(&reports), &served);
}

// ---------------------------------------------------------------------
// Targeted deadline-expiry tests per fault site
// ---------------------------------------------------------------------

/// `slow-worker` (2ms stall per pool lane) pushes a real-clock batch past
/// a 1ms deadline: the GEMM completes, but every row is reported expired
/// at completion rather than served.
#[test]
fn slow_worker_pushes_completion_past_deadline() {
    if hbfp::util::worker_threads() < 2 {
        return; // single-lane dispatch runs inline and never probes the site
    }
    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::SlowWorker,
        rate: 1.0,
        seed: 1,
    }]));
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let cfg = ServeConfig { max_batch_rows: 16, est_ticks_per_row: 0, ..ServeConfig::default() };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(SystemClock::new()));
    let model = srv.register_model("slow-256", &weights(256, 256), 256, 256).unwrap();

    for i in 0..16u64 {
        // 1500us deadline; every armed lane sleeps 2000us, so completion
        // lands past every deadline no matter how fast the GEMM is
        let sub = srv.submit(model, input(256, i), Some(1_500)).unwrap();
        assert!(sub.is_admitted());
    }
    srv.pump().unwrap();
    let m = srv.metrics();
    assert_eq!(m.expired_at_completion, 16, "{m:?}");
    assert_eq!(m.completed, 0);
    assert_eq!(m.latency.count(), 0);
    assert!(srv
        .drain_completions()
        .iter()
        .all(|c| c.outcome == Outcome::Expired(ExpiredAt::Completion)));
}

/// `slow-request` stalls individual requests during batch assembly on the
/// manual clock: deterministic completion-expiry, then dequeue-expiry for
/// work that dies while waiting.
#[test]
fn slow_request_stalls_expire_requests_deterministically() {
    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::SlowRequest,
        rate: 1.0,
        seed: 1,
    }]));
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let cfg = ServeConfig {
        slow_request_penalty_ticks: 2_000,
        synthetic_ticks_per_row: 0,
        est_ticks_per_row: 0,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());
    let model = srv.register_model("stall-8", &weights(8, 8), 8, 8).unwrap();

    // Three rows, 3000-tick deadlines: stalls advance the clock to 2000,
    // 4000, 6000 during assembly, so the whole batch completes at 6000
    // and all three expire at completion.
    for i in 0..3u64 {
        srv.submit(model, input(8, i), Some(3_000)).unwrap();
    }
    let report = srv.pump().unwrap();
    assert_eq!(report.batch.unwrap().ids.len(), 3);
    let m = srv.metrics();
    assert_eq!(m.slow_requests, 3, "{m:?}");
    assert_eq!(m.expired_at_completion, 3);
    assert_eq!(clock.now(), 6_000);

    // Dequeue-expiry: deadlines pass while the requests wait; they are
    // dropped before assembly, so no further stalls are charged.
    for i in 0..2u64 {
        srv.submit(model, input(8, 10 + i), Some(1_000)).unwrap();
    }
    clock.advance(1_500);
    let report = srv.pump().unwrap();
    assert_eq!(report.expired_at_dequeue, 2);
    assert!(report.batch.is_none());
    let m = srv.metrics();
    assert_eq!(m.slow_requests, 3, "expired-at-dequeue rows must not probe the stall site");
    assert_eq!(m.expired_at_dequeue, 2);
}

/// Certain worker panics (rate 1.0): the whole-batch dispatch fails all
/// retries, the per-row split fallback serves every request inline, and
/// each response matches the naive reference for its own 1-row grouping.
#[test]
fn injected_worker_panics_split_but_still_serve() {
    if hbfp::util::worker_threads() < 2 {
        return; // no pool lanes -> the site cannot fire at all
    }
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv =
        InferenceServer::new(ServeConfig { max_gemm_retries: 2, ..ServeConfig::default() },
            ctx, clock);
    let quiet = fault::install(FaultInjector::none());
    let model = srv.register_model("panic-256", &weights(256, 256), 256, 256).unwrap();
    drop(quiet);

    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::WorkerPanic,
        rate: 1.0,
        seed: 4,
    }]));

    let mut inputs = HashMap::new();
    for i in 0..8u64 {
        if let Submission::Admitted { id, .. } =
            srv.submit(model, input(256, i), None).unwrap()
        {
            inputs.insert(id, input(256, i));
        }
    }
    let report = srv.pump().unwrap();
    let batch = report.batch.unwrap();
    assert!(batch.split_fallback, "rate-1.0 panics must force the split fallback");
    assert_eq!(batch.retries, 2);

    let completions = srv.drain_completions();
    let m = srv.metrics();
    assert_eq!(m.completed, 8, "split fallback must serve every row: {m:?}");
    assert_eq!(m.split_fallbacks, 1);
    assert_eq!(m.gemm_retries, 2);
    assert_eq!(m.panics_contained, 3, "initial attempt + 2 retries all contained");
    assert_eq!(m.failed, 0);

    let served = served_map(&completions);
    verify_served_against_naive(&srv, &inputs, &[batch], &served);
}

// ---------------------------------------------------------------------
// Backpressure plumbing
// ---------------------------------------------------------------------

/// With shedding disabled (watermark at capacity) the hard queue bound is
/// the backstop, and it reports `QueueFull`, not `Shedding`.
#[test]
fn queue_full_backstop_when_shedding_disabled() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let cfg = ServeConfig {
        queue_capacity: 4,
        elevated_depth: 4,
        degrade_depth: 4,
        shed_depth: 4,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("tiny", &weights(8, 8), 8, 8).unwrap();
    for i in 0..4u64 {
        assert!(srv.submit(model, input(8, i), None).unwrap().is_admitted());
    }
    assert_eq!(
        srv.submit(model, input(8, 99), None).unwrap(),
        Submission::Rejected(Rejected::QueueFull)
    );
    assert_eq!(srv.metrics().rejected_queue_full, 1);

    // draining one batch reopens admission
    srv.run_until_idle().unwrap();
    assert!(srv.submit(model, input(8, 100), None).unwrap().is_admitted());
}

// ---------------------------------------------------------------------
// Two-tenant flood soak: fair share under a 10x flooding neighbour
// ---------------------------------------------------------------------

fn flood_specs() -> Vec<FaultSpec> {
    vec![
        FaultSpec { site: FaultSite::TenantFlood, rate: 0.5, seed: 23 },
        FaultSpec { site: FaultSite::NanActivation, rate: 0.03, seed: 23 },
        FaultSpec { site: FaultSite::SlowRequest, rate: 0.1, seed: 23 },
    ]
}

fn flood_cfg() -> ServeConfig {
    ServeConfig {
        queue_capacity: 64,
        elevated_depth: 8,
        degrade_depth: 16,
        shed_depth: 32,
        max_batch_rows: 8,
        // small quantum: several DRR rounds per backlog, so fairness (not
        // batch coalescing) is what keeps tenant B's latency bounded
        drr_quantum_rows: 4,
        full_bits: 16,
        degraded_bits: 8,
        default_deadline_ticks: 200_000,
        est_ticks_per_row: 0,
        synthetic_ticks_per_row: 10,
        slow_request_penalty_ticks: 200,
        max_gemm_retries: 2,
        // out of the way: this soak is about scheduling, not quarantine
        breaker: BreakerConfig {
            failure_threshold: 64,
            cooldown_ticks: 10_000,
            half_open_probes: 2,
            expiry_burst: 64,
        },
    }
}

struct FloodRun {
    srv: InferenceServer,
    metrics_json: String,
    completions: Vec<Completion>,
    batches: Vec<BatchReport>,
    inputs: HashMap<u64, Vec<f32>>,
    submitted_a: u64,
    submitted_b: u64,
}

/// Tenant A submits ~10 requests per wave (plus deterministic
/// `tenant-flood` spikes), tenant B exactly one with a real deadline; one
/// pump per wave. Fresh injector per run, manual clock: exact replay.
fn run_flood_once() -> FloodRun {
    let ctx = BfpContext::from_env()
        .with_threads(1)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(flood_cfg(), ctx, clock);
    let quiet = fault::install(FaultInjector::none());
    let a = srv.register_model_with_share("flood-a", &weights(32, 32), 32, 32, 1).unwrap();
    let b = srv.register_model_with_share("tenant-b", &weights(32, 32), 32, 32, 1).unwrap();
    drop(quiet);

    let _g = fault::install(FaultInjector::from_specs(&flood_specs()));

    let mut inputs = HashMap::new();
    let (mut submitted_a, mut submitted_b) = (0u64, 0u64);
    let mut reports = Vec::new();
    for wave in 0..40u64 {
        // the flood driver probes the tenant-flood site: a firing wave
        // spikes tenant A's rate from 10x to 12x tenant B's
        let spike = if fault::fire(FaultSite::TenantFlood) { 4 } else { 2 };
        for j in 0..8 + spike {
            let x = input(32, wave * 100 + j);
            submitted_a += 1;
            if let Submission::Admitted { id, .. } = srv.submit(a, x.clone(), None).unwrap() {
                inputs.insert(id, x);
            }
        }
        let xb = input(32, 10_000 + wave);
        submitted_b += 1;
        if let Submission::Admitted { id, .. } =
            srv.submit(b, xb.clone(), Some(5_000)).unwrap()
        {
            inputs.insert(id, xb);
        }
        reports.push(srv.pump().unwrap());
    }
    reports.extend(srv.run_until_idle().unwrap());

    let completions = srv.drain_completions();
    let metrics_json = srv.metrics_json().to_string();
    let batches = collect_batches(&reports);
    FloodRun { srv, metrics_json, completions, batches, inputs, submitted_a, submitted_b }
}

#[test]
fn two_tenant_flood_soak_keeps_victim_p99_bounded_and_replays_bit_identical() {
    let r1 = run_flood_once();
    let r2 = run_flood_once();

    assert_eq!(r1.metrics_json, r2.metrics_json, "flood soak metrics must replay identically");
    assert_eq!(r1.completions, r2.completions, "flood soak outcomes must replay identically");

    let m = r1.srv.metrics();
    let (ma, mb) = (&m.models[0], &m.models[1]);

    // A really flooded: ~10x B's submission rate, shed ladder engaged.
    assert!(r1.submitted_a >= 10 * r1.submitted_b);
    assert!(m.rejected_shedding > 0, "flooding tenant never hit the shed watermark: {m:?}");
    assert!(ma.admitted < r1.submitted_a, "some of the flood must be shed");
    assert_eq!(mb.admitted, r1.submitted_b, "the victim tenant must never be rejected");

    // Fair share: B's p99 stays under its 5000-tick deadline even though
    // A holds a 4x-deeper backlog the whole run, and not one B request
    // expires. A degrades under its own backlog; B never does.
    assert_eq!(mb.expired, 0, "victim tenant lost requests to the flood: {mb:?}");
    assert!(
        mb.latency.p99() <= 5_000,
        "victim p99 {} breached its deadline under the flood",
        mb.latency.p99()
    );
    assert!(ma.degraded > 0, "the flooding tenant should degrade under its own backlog");
    assert_eq!(mb.degraded, 0, "the victim tenant must not inherit A's degradation");

    // Per-tenant conservation: every admitted request terminates exactly
    // once inside its own tenant's accounting.
    for (name, t) in [("a", ma), ("b", mb)] {
        assert_eq!(
            t.admitted,
            t.served + t.expired + t.failed,
            "tenant {name} leaked requests: {t:?}"
        );
    }
    assert_eq!(m.admitted, ma.admitted + mb.admitted);
    assert_eq!(r1.completions.len() as u64, m.admitted);
    assert_eq!(r1.srv.queue_depth(), 0);

    // Everything served is still bit-identical to the naive reference.
    let served = served_map(&r1.completions);
    verify_served_against_naive(&r1.srv, &r1.inputs, &r1.batches, &served);
}

// ---------------------------------------------------------------------
// Circuit breaker: trip, quarantine, half-open recovery
// ---------------------------------------------------------------------

#[test]
fn breaker_quarantines_poisoned_tenant_and_recovers_via_probes() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let cfg = ServeConfig {
        max_batch_rows: 4,
        drr_quantum_rows: 4,
        synthetic_ticks_per_row: 10,
        est_ticks_per_row: 0,
        breaker: BreakerConfig {
            failure_threshold: 3,
            cooldown_ticks: 1_000,
            half_open_probes: 2,
            expiry_burst: 64,
        },
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());
    let sick = srv.register_model("sick", &weights(8, 8), 8, 8).unwrap();
    let healthy = srv.register_model("healthy", &weights(8, 8), 8, 8).unwrap();

    // Three poisoned rows and one good one ride the first batch; a fifth
    // request stays queued behind it.
    for i in 0..3u64 {
        let mut x = input(8, i);
        x[0] = f32::NAN;
        assert!(srv.submit(sick, x, None).unwrap().is_admitted());
    }
    assert!(srv.submit(sick, input(8, 50), None).unwrap().is_admitted());
    assert!(srv.submit(sick, input(8, 51), None).unwrap().is_admitted());
    srv.pump().unwrap();

    // The third consecutive failure trips the breaker mid-settlement: the
    // queued fifth request is flushed as Failed, the good batch-mate
    // (already executed) still serves.
    let m = srv.metrics();
    assert_eq!(m.breaker_trips, 1, "{m:?}");
    assert_eq!(m.completed, 1);
    assert_eq!(m.failed, 4, "3 poisoned rows + 1 flushed on quarantine: {m:?}");
    assert!(matches!(srv.breaker_state(sick), Some(BreakerState::Open { .. })));
    assert_eq!(srv.model_queue_depth(sick), 0, "quarantine must flush the tenant's queue");
    let completions = srv.drain_completions();
    assert_eq!(completions.len(), 5);
    assert!(completions
        .iter()
        .any(|c| matches!(&c.outcome, Outcome::Failed(msg) if msg.contains("quarantined"))));

    // Quarantined: new submissions are refused with the typed reason; the
    // healthy neighbour is completely unaffected.
    assert_eq!(
        srv.submit(sick, input(8, 60), None).unwrap(),
        Submission::Rejected(Rejected::Quarantined)
    );
    assert_eq!(srv.metrics().rejected_quarantined, 1);
    assert_eq!(srv.metrics().models[sick].quarantined, 1);
    assert!(srv.submit(healthy, input(8, 61), None).unwrap().is_admitted());
    srv.pump().unwrap();
    assert_eq!(srv.metrics().models[healthy].served, 1);
    assert!(matches!(srv.breaker_state(healthy), Some(BreakerState::Closed)));

    // After the cooldown the breaker half-opens: exactly
    // `half_open_probes` requests are admitted, the rest still refused.
    clock.advance(2_000);
    assert!(srv.submit(sick, input(8, 70), None).unwrap().is_admitted());
    assert!(matches!(srv.breaker_state(sick), Some(BreakerState::HalfProbe { .. })));
    assert!(srv.submit(sick, input(8, 71), None).unwrap().is_admitted());
    assert_eq!(
        srv.submit(sick, input(8, 72), None).unwrap(),
        Submission::Rejected(Rejected::Quarantined),
        "probe slots are capped while half-open"
    );

    // Both probes succeed -> the breaker closes and service resumes.
    srv.run_until_idle().unwrap();
    let m = srv.metrics();
    assert!(matches!(srv.breaker_state(sick), Some(BreakerState::Closed)));
    assert_eq!(m.breaker_recoveries, 1, "{m:?}");
    assert_eq!(m.models[sick].served, 3, "good row + 2 probes: {m:?}");
    assert!(srv.submit(sick, input(8, 80), None).unwrap().is_admitted());
    srv.run_until_idle().unwrap();
    assert_eq!(srv.metrics().models[sick].served, 4);
}

// ---------------------------------------------------------------------
// Hot reload: garbled rollback and clean mid-burst swap
// ---------------------------------------------------------------------

fn reload_cfg() -> ServeConfig {
    ServeConfig {
        max_batch_rows: 4,
        drr_quantum_rows: 4,
        synthetic_ticks_per_row: 10,
        est_ticks_per_row: 0,
        ..ServeConfig::default()
    }
}

fn weights_v2(k: usize, n: usize) -> Vec<f32> {
    weights(k, n).iter().map(|w| w * 0.8 - 0.05).collect()
}

/// Mid-burst `reload_model` under `reload-garble`: validation catches the
/// corrupted build, the swap is rolled back, and every in-flight request
/// — already-batched and still-queued alike — serves on the old
/// generation. Zero responses from the garbled candidate, zero drops.
#[test]
fn garbled_reload_mid_burst_rolls_back_and_keeps_serving_old_generation() {
    let quiet = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let mut srv = InferenceServer::new(reload_cfg(), ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("reload-16", &weights(16, 16), 16, 16).unwrap();

    let mut inputs = HashMap::new();
    for i in 0..12u64 {
        if let Submission::Admitted { id, .. } =
            srv.submit(model, input(16, i), None).unwrap()
        {
            inputs.insert(id, input(16, i));
        }
    }
    let mut reports = vec![srv.pump().unwrap()];
    drop(quiet);

    // Mid-burst reload with a certain garble: typed validation failure.
    let g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::ReloadGarble,
        rate: 1.0,
        seed: 7,
    }]));
    let err = srv.reload_model(model, &weights_v2(16, 16)).unwrap_err();
    assert!(matches!(err, ReloadError::Validation(_)), "got {err}");
    drop(g);

    let _quiet = fault::install(FaultInjector::none());
    reports.extend(srv.run_until_idle().unwrap());

    let m = srv.metrics();
    assert_eq!(m.reload_rollbacks, 1, "{m:?}");
    assert_eq!(m.reloads, 0);
    assert_eq!(srv.model(model).unwrap().generation(), 0, "rollback must keep generation 0");
    assert_eq!(m.breaker_trips, 0, "a failed reload must not trip the breaker");

    // Nothing dropped, nothing served off the garbled build.
    let completions = srv.drain_completions();
    assert_eq!(completions.len(), 12);
    let served = served_map(&completions);
    assert_eq!(served.len(), 12, "a rolled-back reload must not cost a single request");
    assert!(served.values().all(|r| r.generation == 0));
    let batches = collect_batches(&reports);
    assert!(batches.iter().all(|b| b.generation == 0));
    verify_served_against_naive(&srv, &inputs, &batches, &served);
}

struct ReloadBurstRun {
    srv: InferenceServer,
    burst: Vec<Completion>,
    fresh: Vec<Completion>,
    fresh_batches: Vec<BatchReport>,
    inputs: HashMap<u64, Vec<f32>>,
}

/// The same burst schedule with and without a mid-burst *clean* reload:
/// the reload swaps generations atomically between pumps and does not add
/// a single expiry the burst would not have had anyway.
fn reload_burst_run(reload_mid_burst: bool) -> ReloadBurstRun {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let mut srv = InferenceServer::new(reload_cfg(), ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("swap-16", &weights(16, 16), 16, 16).unwrap();

    // 12 rows at 10 ticks each, 75-tick deadlines, 4-row batches: rows
    // 0-3 serve at t=40, rows 4-7 complete at t=80 and expire, rows 8-11
    // die in the queue.
    for i in 0..12u64 {
        assert!(srv.submit(model, input(16, i), Some(75)).unwrap().is_admitted());
    }
    srv.pump().unwrap();
    if reload_mid_burst {
        let report = srv.reload_model(model, &weights_v2(16, 16)).unwrap();
        assert_eq!((report.old_generation, report.new_generation), (0, 1));
        assert_eq!(report.validated_widths, (16, 8));
    }
    srv.run_until_idle().unwrap();
    let burst = srv.drain_completions();

    // Post-burst traffic serves on whatever generation is resident now.
    let mut inputs = HashMap::new();
    for i in 100..102u64 {
        let x = input(16, i);
        if let Submission::Admitted { id, .. } = srv.submit(model, x.clone(), None).unwrap() {
            inputs.insert(id, x);
        }
    }
    let reports = srv.run_until_idle().unwrap();
    let fresh = srv.drain_completions();
    let fresh_batches = collect_batches(&reports);
    ReloadBurstRun { srv, burst, fresh, fresh_batches, inputs }
}

#[test]
fn clean_mid_burst_reload_swaps_generation_without_extra_expiries() {
    let control = reload_burst_run(false);
    let reloaded = reload_burst_run(true);

    // The burst outcomes are bit-identical with and without the reload:
    // same serves, same expiries, same latencies. The swap is free.
    assert_eq!(control.burst, reloaded.burst, "a clean reload altered in-flight outcomes");
    let mc = control.srv.metrics();
    let mr = reloaded.srv.metrics();
    assert_eq!(mc.expired_at_completion, 4);
    assert_eq!(mc.expired_at_dequeue, 4);
    assert_eq!(
        (mc.expired_at_completion, mc.expired_at_dequeue),
        (mr.expired_at_completion, mr.expired_at_dequeue),
        "a clean reload must not add expiries"
    );
    assert_eq!(mr.reloads, 1);
    assert_eq!(mr.reload_rollbacks, 0);

    // Pre-reload serves are generation 0 in both runs; post-reload
    // traffic is generation 1 only in the reloaded server, and its
    // outputs match the naive reference on the *new* resident weights
    // (the verifier reads the server's current residency, which after
    // the swap is the generation-1 tensors).
    assert!(served_map(&control.burst).values().all(|r| r.generation == 0));
    assert!(served_map(&reloaded.burst).values().all(|r| r.generation == 0));
    assert_eq!(control.srv.model(0).unwrap().generation(), 0);
    assert_eq!(reloaded.srv.model(0).unwrap().generation(), 1);
    assert!(served_map(&control.fresh).values().all(|r| r.generation == 0));
    let fresh_served = served_map(&reloaded.fresh);
    assert_eq!(fresh_served.len(), 2);
    assert!(fresh_served.values().all(|r| r.generation == 1));
    assert!(reloaded.fresh_batches.iter().all(|b| b.generation == 1));
    verify_served_against_naive(
        &reloaded.srv,
        &reloaded.inputs,
        &reloaded.fresh_batches,
        &fresh_served,
    );
}

#[test]
fn reload_rejects_unknown_model_shape_mismatch_and_nonfinite() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let mut srv = InferenceServer::new(reload_cfg(), ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("small", &weights(8, 8), 8, 8).unwrap();

    assert!(matches!(
        srv.reload_model(7, &weights(8, 8)),
        Err(ReloadError::UnknownModel(7))
    ));
    assert!(matches!(
        srv.reload_model(model, &weights(8, 4)),
        Err(ReloadError::ShapeMismatch { expected: 64, got: 32 })
    ));
    let mut bad = weights(8, 8);
    bad[5] = f32::INFINITY;
    assert!(matches!(srv.reload_model(model, &bad), Err(ReloadError::Validation(_))));
    assert_eq!(srv.model(model).unwrap().generation(), 0);
    // only the candidate runs that reached validation count as rollbacks
    assert_eq!(srv.metrics().reload_rollbacks, 1);
}

// ---------------------------------------------------------------------
// Graceful drain
// ---------------------------------------------------------------------

#[test]
fn drain_during_burst_reaches_stopped_with_conservation() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let cfg = ServeConfig {
        max_batch_rows: 4,
        drr_quantum_rows: 4,
        synthetic_ticks_per_row: 10,
        est_ticks_per_row: 0,
        default_deadline_ticks: 1_000_000,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());
    let a = srv.register_model("drain-a", &weights(8, 8), 8, 8).unwrap();
    let b = srv.register_model("drain-b", &weights(8, 8), 8, 8).unwrap();

    for i in 0..10u64 {
        assert!(srv.submit(a, input(8, i), None).unwrap().is_admitted());
        assert!(srv.submit(b, input(8, 100 + i), None).unwrap().is_admitted());
    }
    srv.pump().unwrap();
    srv.pump().unwrap();
    assert!(srv.is_ready());

    // Drain: admission slams shut with the typed reason, admitted work
    // keeps pumping, and whatever is still queued at the deadline is
    // force-expired rather than abandoned.
    let deadline = srv.begin_drain(100).unwrap();
    assert_eq!(deadline, clock.now() + 100);
    assert!(!srv.is_ready());
    assert!(matches!(srv.lifecycle(), Lifecycle::Draining { .. }));
    assert_eq!(
        srv.submit(a, input(8, 999), None).unwrap(),
        Submission::Rejected(Rejected::Draining)
    );
    assert_eq!(srv.metrics().rejected_draining, 1);
    // begin_drain is idempotent while draining: same deadline back
    assert_eq!(srv.begin_drain(5_000).unwrap(), deadline);

    let report = srv.run_until_stopped().unwrap();
    assert!(report.conserved, "drain accounting must conserve: {report:?}");
    assert_eq!(report.admitted, 20);
    assert!(report.force_expired > 0, "the deadline must have cut off queued work: {report:?}");
    assert_eq!(report.served + report.expired + report.force_expired + report.failed, 20);
    assert!(matches!(srv.lifecycle(), Lifecycle::Stopped));
    assert_eq!(srv.queue_depth(), 0);
    assert_eq!(srv.metrics().expired_at_drain, report.force_expired);

    // Stopped is terminal: pumps are no-ops, drains cannot restart, and
    // every admitted id completed exactly once.
    assert!(!srv.pump().unwrap().made_progress());
    assert!(srv.begin_drain(10).is_err());
    let completions = srv.drain_completions();
    assert_eq!(completions.len(), 20);
    let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 20, "every admitted request must terminate exactly once");
    assert!(completions
        .iter()
        .any(|c| c.outcome == Outcome::Expired(ExpiredAt::DrainDeadline)));
    for t in &srv.metrics().models {
        assert_eq!(t.admitted, t.served + t.expired + t.failed, "tenant leak: {t:?}");
    }
}

// ---------------------------------------------------------------------
// Lifecycle soak under environment faults (chaos-lifecycle CI target)
// ---------------------------------------------------------------------

/// Runs *under* `HBFP_FAULT` — the chaos-lifecycle matrix arms
/// `reload-garble`, `worker-panic`, and `tenant-flood` here. Two tenants,
/// deterministic flood bursts driven by the tenant-flood site, a
/// mid-burst hot reload that must either swap cleanly or roll back
/// (never drop work), then a full drain to `Stopped` with conservation.
#[test]
fn lifecycle_soak_survives_environment_faults() {
    let _env = fault::exclusive();
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let cfg = ServeConfig {
        queue_capacity: 16,
        elevated_depth: 4,
        degrade_depth: 6,
        shed_depth: 12,
        max_batch_rows: 4,
        drr_quantum_rows: 4,
        est_ticks_per_row: 0,
        synthetic_ticks_per_row: 100,
        default_deadline_ticks: 40_000,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, clock);
    let a = srv.register_model_with_share("chaos-a", &weights(16, 16), 16, 16, 2).unwrap();
    let b = srv.register_model_with_share("chaos-b", &weights(16, 16), 16, 16, 1).unwrap();

    let mut submitted = 0u64;
    for wave in 0..16u64 {
        let burst = if fault::fire(FaultSite::TenantFlood) { 6 } else { 2 };
        for j in 0..burst {
            srv.submit(a, input(16, wave * 10 + j), None).unwrap();
            submitted += 1;
        }
        srv.submit(b, input(16, 1_000 + wave), None).unwrap();
        submitted += 1;
        if wave % 2 == 1 {
            srv.pump().unwrap();
        }
        if wave == 7 {
            // Mid-burst reload under whatever the env armed: a clean env
            // swaps to generation 1; an armed reload-garble rolls back to
            // generation 0. Both leave a serving model and drop nothing.
            match srv.reload_model(a, &weights_v2(16, 16)) {
                Ok(r) => {
                    assert_eq!(r.new_generation, srv.model(a).unwrap().generation());
                    assert_eq!(srv.metrics().reloads, 1);
                }
                Err(ReloadError::Validation(_)) => {
                    assert_eq!(srv.model(a).unwrap().generation(), 0);
                    assert_eq!(srv.metrics().reload_rollbacks, 1);
                }
                Err(e) => panic!("unexpected reload error: {e}"),
            }
        }
    }

    srv.begin_drain(5_000).unwrap();
    let report = srv.run_until_stopped().unwrap();
    assert!(report.conserved, "lifecycle soak must conserve under env faults: {report:?}");
    assert!(matches!(srv.lifecycle(), Lifecycle::Stopped));
    assert_eq!(srv.queue_depth(), 0);

    let m = srv.metrics();
    assert_eq!(submitted, m.admitted + m.rejected_total());
    let completions = srv.drain_completions();
    assert_eq!(completions.len() as u64, m.admitted);
    for t in &m.models {
        assert_eq!(t.admitted, t.served + t.expired + t.failed, "tenant leak: {t:?}");
    }
}
