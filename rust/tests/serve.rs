//! Serving front-end integration: the deterministic overload soak
//! (admission ladder, deadlines, degradation, contained faults — replayed
//! twice and compared bit-for-bit), targeted deadline-expiry tests for the
//! `slow-worker` and `slow-request` fault sites, contained worker-panic
//! retry/split-fallback, and the environment-fault soak the CI
//! fault-injection matrix drives.
//!
//! Injector discipline (same as `fault_tolerance.rs`): every test either
//! `install`s an explicit injector — which serializes it on the harness's
//! install lock and shields it from `HBFP_FAULT` and from its neighbors —
//! or holds `fault::exclusive()` to run *under* the environment's
//! injector.

use std::collections::HashMap;
use std::sync::Arc;

use hbfp::bfp::{bfp_matmul_naive, BfpContext, Isa, Rounding, TileSize};
use hbfp::serve::{
    BatchReport, Completion, ExpiredAt, InferenceServer, ManualClock, Outcome, PumpReport,
    Rejected, Response, ServeConfig, Submission, SystemClock,
};
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};

fn weights(k: usize, n: usize) -> Vec<f32> {
    (0..k * n).map(|i| ((i as f32) * 0.173).sin() * 0.5).collect()
}

fn input(k: usize, salt: u64) -> Vec<f32> {
    (0..k).map(|i| ((i as f32) * 0.31 + salt as f32 * 0.77).cos()).collect()
}

/// Replay every served response against the naive BFP reference at the
/// width and batch grouping the server reported for it. Whole batches are
/// quantized as one `m x k` operand; split-fallback batches quantize each
/// row independently (that is what the server executed).
fn verify_served_against_naive(
    srv: &InferenceServer,
    inputs: &HashMap<u64, Vec<f32>>,
    batches: &[BatchReport],
    served: &HashMap<u64, Response>,
) {
    let ctx = srv.context();
    let mut checked = 0usize;
    for b in batches {
        let model = srv.model(b.model).unwrap();
        let (k, n) = (model.k(), model.n());
        let wb = model.weights_at(b.bits);
        if b.ids.is_empty() {
            continue;
        }
        if b.split_fallback {
            for id in &b.ids {
                let Some(resp) = served.get(id) else { continue };
                let qa = ctx
                    .quantize(&inputs[id], 1, k, b.bits, &mut Rounding::NearestEven)
                    .unwrap();
                let want = bfp_matmul_naive(&qa, wb).unwrap();
                assert_eq!(resp.output, want, "split row {id} diverged from naive");
                assert_eq!(resp.served_bits, b.bits);
                checked += 1;
            }
        } else {
            let m = b.ids.len();
            let mut flat = Vec::with_capacity(m * k);
            for id in &b.ids {
                flat.extend_from_slice(&inputs[id]);
            }
            let qa = ctx.quantize(&flat, m, k, b.bits, &mut Rounding::NearestEven).unwrap();
            let want = bfp_matmul_naive(&qa, wb).unwrap();
            for (i, id) in b.ids.iter().enumerate() {
                let Some(resp) = served.get(id) else { continue };
                assert_eq!(
                    resp.output,
                    want[i * n..(i + 1) * n].to_vec(),
                    "batched row {id} diverged from naive"
                );
                assert_eq!(resp.served_bits, b.bits);
                assert_eq!(resp.degraded, b.degraded);
                checked += 1;
            }
        }
    }
    assert!(checked > 0, "verification must cover at least one served response");
}

fn served_map(completions: &[Completion]) -> HashMap<u64, Response> {
    completions
        .iter()
        .filter_map(|c| match &c.outcome {
            Outcome::Served(r) => Some((c.id, r.clone())),
            _ => None,
        })
        .collect()
}

fn collect_batches(reports: &[PumpReport]) -> Vec<BatchReport> {
    reports.iter().filter_map(|r| r.batch.clone()).collect()
}

// ---------------------------------------------------------------------
// The deterministic overload soak (the acceptance scenario)
// ---------------------------------------------------------------------

/// Mirrors the CI overload-soak leg's HBFP_FAULT spec. Installed
/// explicitly so the test is the same everywhere.
fn soak_specs() -> Vec<FaultSpec> {
    vec![
        FaultSpec { site: FaultSite::WorkerPanic, rate: 0.35, seed: 11 },
        FaultSpec { site: FaultSite::SlowWorker, rate: 0.5, seed: 11 },
        FaultSpec { site: FaultSite::NanActivation, rate: 0.05, seed: 11 },
        FaultSpec { site: FaultSite::SlowRequest, rate: 0.25, seed: 11 },
    ]
}

fn soak_cfg() -> ServeConfig {
    ServeConfig {
        queue_capacity: 32,
        elevated_depth: 8,
        degrade_depth: 12,
        shed_depth: 24,
        max_batch_rows: 16,
        full_bits: 16,
        degraded_bits: 8,
        default_deadline_ticks: 50_000,
        est_ticks_per_row: 200,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        max_gemm_retries: 2,
    }
}

struct SoakRun {
    srv: InferenceServer,
    metrics_json: String,
    completions: Vec<Completion>,
    batches: Vec<BatchReport>,
    inputs: HashMap<u64, Vec<f32>>,
    submitted: u64,
}

fn soak_request(
    srv: &mut InferenceServer,
    model: usize,
    i: u64,
    inputs: &mut HashMap<u64, Vec<f32>>,
) {
    let mut x = input(256, i);
    if i % 13 == 12 {
        // a poisoned client payload rides along every 13th request
        x[2] = f32::NAN;
    }
    // every 7th request carries a tight deadline (Overloaded fodder once
    // a backlog exists), every 7k+3rd a mid deadline, the rest default
    let deadline = match i % 7 {
        0 => Some(300),
        3 => Some(6_000),
        _ => None,
    };
    if let Submission::Admitted { id, .. } = srv.submit(model, x.clone(), deadline).unwrap() {
        inputs.insert(id, x);
    }
}

fn run_soak_once() -> SoakRun {
    // Fixed tile/ISA/threads so the lane layout — and therefore the fault
    // probe schedule — does not depend on the host's vector unit.
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(soak_cfg(), ctx, clock.clone());
    // Model load runs shielded: the soak injects faults into serving, not
    // into residency building (whose pool dispatches are uncontained).
    let quiet = fault::install(FaultInjector::none());
    let model = srv.register_model("soak-256", &weights(256, 256), 256, 256).unwrap();
    drop(quiet);

    // A fresh injector per run resets the probe counters, which is what
    // makes the replay exact.
    let _g = fault::install(FaultInjector::from_specs(&soak_specs()));

    let mut inputs = HashMap::new();
    let mut submitted = 0u64;
    let mut reports = Vec::new();

    // Phase A: a 33-request burst with no pump — climbs the whole ladder
    // (nominal -> elevated -> degraded -> shedding) at twice the
    // admission capacity the shed watermark allows.
    for i in 0..33u64 {
        soak_request(&mut srv, model, i, &mut inputs);
        submitted += 1;
    }

    // Phase B: sustained 2x overload — 6 new requests per pump while each
    // pump retires at most 16 rows from a 24-deep backlog.
    for wave in 0..12u64 {
        for j in 0..6u64 {
            soak_request(&mut srv, model, 33 + wave * 6 + j, &mut inputs);
            submitted += 1;
        }
        reports.push(srv.pump().unwrap());
    }

    // Phase C: drain.
    reports.extend(srv.run_until_idle().unwrap());

    // Coda: one feasible-at-admission request that dies in the queue —
    // the deterministic dequeue-expiry case.
    let sub = srv.submit(model, input(256, 9_999), Some(300)).unwrap();
    assert!(sub.is_admitted(), "empty queue must admit a 300-tick deadline");
    if let Submission::Admitted { id, .. } = sub {
        inputs.insert(id, input(256, 9_999));
    }
    submitted += 1;
    clock.advance(400);
    reports.extend(srv.run_until_idle().unwrap());

    let completions = srv.drain_completions();
    let metrics_json = srv.metrics_json().to_string();
    let batches = collect_batches(&reports);
    SoakRun { srv, metrics_json, completions, batches, inputs, submitted }
}

#[test]
fn overload_soak_is_deterministic_and_serves_bit_identical() {
    let r1 = run_soak_once();
    let r2 = run_soak_once();

    // Replay: identical metrics (counters, histogram, plan cache) and
    // identical per-request outcomes including every output bit.
    assert_eq!(r1.metrics_json, r2.metrics_json, "soak metrics must replay identically");
    assert_eq!(r1.completions, r2.completions, "soak outcomes must replay identically");

    let m = r1.srv.metrics();

    // Conservation: every submission is accounted for exactly once.
    assert_eq!(r1.submitted, m.admitted + m.rejected_total());
    assert_eq!(m.admitted as usize, r1.inputs.len());
    assert_eq!(
        m.admitted,
        m.completed + m.expired_at_dequeue + m.expired_at_completion + m.failed,
        "admitted requests must all terminate: {m:?}"
    );
    assert_eq!(r1.completions.len() as u64, m.admitted);
    assert_eq!(r1.srv.queue_depth(), 0);

    // The ladder actually engaged under 2x load.
    assert!(m.rejected_shedding > 0, "shed watermark never hit: {m:?}");
    assert!(m.rejected_overloaded > 0, "deadline feasibility screen never hit: {m:?}");
    assert!(m.degraded_served > 0, "precision degradation never engaged: {m:?}");
    assert!(m.expired_at_dequeue > 0, "no dequeue expiry: {m:?}");
    assert!(m.expired_at_completion > 0, "no completion expiry: {m:?}");
    assert!(m.failed > 0, "poisoned payloads must fail individually: {m:?}");

    // Deadline SLO: the histogram only holds served requests, and no
    // request was admitted with more than the 50k-tick default.
    assert_eq!(m.latency.count(), m.completed);
    assert!(m.latency.p99() <= 50_000, "p99 {} above deadline ceiling", m.latency.p99());
    assert!(m.latency.p50() <= m.latency.p99());

    // With multiple pool lanes the worker-panic site must have been
    // contained (never escaped: the run finished and drained).
    if hbfp::util::worker_threads() >= 2 {
        assert!(m.panics_contained > 0, "worker-panic armed but never contained: {m:?}");
    }

    // Every served response is bit-identical to the naive reference at
    // its served width and batch grouping.
    let served = served_map(&r1.completions);
    assert_eq!(served.len() as u64, m.completed);
    verify_served_against_naive(&r1.srv, &r1.inputs, &r1.batches, &served);

    // Degraded responses are flagged and narrow.
    let degraded: Vec<&Response> = served.values().filter(|r| r.degraded).collect();
    assert_eq!(degraded.len() as u64, m.degraded_served);
    assert!(degraded.iter().all(|r| r.served_bits == 8));
}

// ---------------------------------------------------------------------
// Environment-fault soak (the CI fault-injection matrix target)
// ---------------------------------------------------------------------

/// Runs *under* `HBFP_FAULT` (whatever the environment armed, if
/// anything) and checks the robustness invariants only: the queue drains,
/// nothing escapes, accounting conserves, and everything served is still
/// bit-identical to naive. Single-lane context so an env worker-panic
/// cannot unwind model registration, which runs outside the serve loop's
/// containment.
#[test]
fn soak_survives_environment_faults() {
    let _env = fault::exclusive();
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let cfg = ServeConfig {
        queue_capacity: 16,
        elevated_depth: 4,
        degrade_depth: 6,
        shed_depth: 12,
        max_batch_rows: 8,
        est_ticks_per_row: 150,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        default_deadline_ticks: 40_000,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("env-64", &weights(64, 64), 64, 64).unwrap();

    let mut inputs = HashMap::new();
    let mut submitted = 0u64;
    let mut reports = Vec::new();
    for i in 0..60u64 {
        let x = input(64, i);
        let deadline = if i % 9 == 4 { Some(1_200) } else { None };
        if let Submission::Admitted { id, .. } = srv.submit(model, x.clone(), deadline).unwrap()
        {
            inputs.insert(id, x);
        }
        submitted += 1;
        if i % 4 == 3 {
            reports.push(srv.pump().unwrap());
        }
    }
    reports.extend(srv.run_until_idle().unwrap());

    let completions = srv.drain_completions();
    let m = srv.metrics();
    assert_eq!(srv.queue_depth(), 0, "queue must drain under env faults");
    assert_eq!(submitted, m.admitted + m.rejected_total());
    assert_eq!(
        m.admitted,
        m.completed + m.expired_at_dequeue + m.expired_at_completion + m.failed
    );
    assert_eq!(completions.len() as u64, m.admitted);
    assert!(m.completed > 0, "env faults must not starve service: {m:?}");

    let served = served_map(&completions);
    verify_served_against_naive(&srv, &inputs, &collect_batches(&reports), &served);
}

// ---------------------------------------------------------------------
// Targeted deadline-expiry tests per fault site
// ---------------------------------------------------------------------

/// `slow-worker` (2ms stall per pool lane) pushes a real-clock batch past
/// a 1ms deadline: the GEMM completes, but every row is reported expired
/// at completion rather than served.
#[test]
fn slow_worker_pushes_completion_past_deadline() {
    if hbfp::util::worker_threads() < 2 {
        return; // single-lane dispatch runs inline and never probes the site
    }
    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::SlowWorker,
        rate: 1.0,
        seed: 1,
    }]));
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let cfg = ServeConfig { max_batch_rows: 16, est_ticks_per_row: 0, ..ServeConfig::default() };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(SystemClock::new()));
    let model = srv.register_model("slow-256", &weights(256, 256), 256, 256).unwrap();

    for i in 0..16u64 {
        // 1500us deadline; every armed lane sleeps 2000us, so completion
        // lands past every deadline no matter how fast the GEMM is
        let sub = srv.submit(model, input(256, i), Some(1_500)).unwrap();
        assert!(sub.is_admitted());
    }
    srv.pump().unwrap();
    let m = srv.metrics();
    assert_eq!(m.expired_at_completion, 16, "{m:?}");
    assert_eq!(m.completed, 0);
    assert_eq!(m.latency.count(), 0);
    assert!(srv
        .drain_completions()
        .iter()
        .all(|c| c.outcome == Outcome::Expired(ExpiredAt::Completion)));
}

/// `slow-request` stalls individual requests during batch assembly on the
/// manual clock: deterministic completion-expiry, then dequeue-expiry for
/// work that dies while waiting.
#[test]
fn slow_request_stalls_expire_requests_deterministically() {
    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::SlowRequest,
        rate: 1.0,
        seed: 1,
    }]));
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let cfg = ServeConfig {
        slow_request_penalty_ticks: 2_000,
        synthetic_ticks_per_row: 0,
        est_ticks_per_row: 0,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());
    let model = srv.register_model("stall-8", &weights(8, 8), 8, 8).unwrap();

    // Three rows, 3000-tick deadlines: stalls advance the clock to 2000,
    // 4000, 6000 during assembly, so the whole batch completes at 6000
    // and all three expire at completion.
    for i in 0..3u64 {
        srv.submit(model, input(8, i), Some(3_000)).unwrap();
    }
    let report = srv.pump().unwrap();
    assert_eq!(report.batch.unwrap().ids.len(), 3);
    let m = srv.metrics();
    assert_eq!(m.slow_requests, 3, "{m:?}");
    assert_eq!(m.expired_at_completion, 3);
    assert_eq!(clock.now(), 6_000);

    // Dequeue-expiry: deadlines pass while the requests wait; they are
    // dropped before assembly, so no further stalls are charged.
    for i in 0..2u64 {
        srv.submit(model, input(8, 10 + i), Some(1_000)).unwrap();
    }
    clock.advance(1_500);
    let report = srv.pump().unwrap();
    assert_eq!(report.expired_at_dequeue, 2);
    assert!(report.batch.is_none());
    let m = srv.metrics();
    assert_eq!(m.slow_requests, 3, "expired-at-dequeue rows must not probe the stall site");
    assert_eq!(m.expired_at_dequeue, 2);
}

/// Certain worker panics (rate 1.0): the whole-batch dispatch fails all
/// retries, the per-row split fallback serves every request inline, and
/// each response matches the naive reference for its own 1-row grouping.
#[test]
fn injected_worker_panics_split_but_still_serve() {
    if hbfp::util::worker_threads() < 2 {
        return; // no pool lanes -> the site cannot fire at all
    }
    let ctx = BfpContext::from_env()
        .with_threads(4)
        .with_isa(Isa::Scalar)
        .with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv =
        InferenceServer::new(ServeConfig { max_gemm_retries: 2, ..ServeConfig::default() },
            ctx, clock);
    let quiet = fault::install(FaultInjector::none());
    let model = srv.register_model("panic-256", &weights(256, 256), 256, 256).unwrap();
    drop(quiet);

    let _g = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::WorkerPanic,
        rate: 1.0,
        seed: 4,
    }]));

    let mut inputs = HashMap::new();
    for i in 0..8u64 {
        if let Submission::Admitted { id, .. } =
            srv.submit(model, input(256, i), None).unwrap()
        {
            inputs.insert(id, input(256, i));
        }
    }
    let report = srv.pump().unwrap();
    let batch = report.batch.unwrap();
    assert!(batch.split_fallback, "rate-1.0 panics must force the split fallback");
    assert_eq!(batch.retries, 2);

    let completions = srv.drain_completions();
    let m = srv.metrics();
    assert_eq!(m.completed, 8, "split fallback must serve every row: {m:?}");
    assert_eq!(m.split_fallbacks, 1);
    assert_eq!(m.gemm_retries, 2);
    assert_eq!(m.panics_contained, 3, "initial attempt + 2 retries all contained");
    assert_eq!(m.failed, 0);

    let served = served_map(&completions);
    verify_served_against_naive(&srv, &inputs, &[batch], &served);
}

// ---------------------------------------------------------------------
// Backpressure plumbing
// ---------------------------------------------------------------------

/// With shedding disabled (watermark at capacity) the hard queue bound is
/// the backstop, and it reports `QueueFull`, not `Shedding`.
#[test]
fn queue_full_backstop_when_shedding_disabled() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
    let cfg = ServeConfig {
        queue_capacity: 4,
        elevated_depth: 4,
        degrade_depth: 4,
        shed_depth: 4,
        ..ServeConfig::default()
    };
    let mut srv = InferenceServer::new(cfg, ctx, Arc::new(ManualClock::new()));
    let model = srv.register_model("tiny", &weights(8, 8), 8, 8).unwrap();
    for i in 0..4u64 {
        assert!(srv.submit(model, input(8, i), None).unwrap().is_admitted());
    }
    assert_eq!(
        srv.submit(model, input(8, 99), None).unwrap(),
        Submission::Rejected(Rejected::QueueFull)
    );
    assert_eq!(srv.metrics().rejected_queue_full, 1);

    // draining one batch reopens admission
    srv.run_until_idle().unwrap();
    assert!(srv.submit(model, input(8, 100), None).unwrap().is_admitted());
}
