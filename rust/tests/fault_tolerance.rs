//! Fault-tolerance integration: checkpoint corruption survival, pool
//! panic containment, guarded-GEMM degradation, and the acceptance demo
//! (mid-run NaN + truncated checkpoint → detect → rollback → widen →
//! finish with a valid, deterministic metrics history).
//!
//! Injector discipline: this binary's tests either `install` an explicit
//! injector (which serializes them on the harness's install lock and
//! shields them from each other and from `HBFP_FAULT`), or hold
//! `fault::exclusive()` to run *under* the environment's injector — the
//! CI fault-injection matrix points `HBFP_FAULT` at this test binary.

use std::path::PathBuf;

use hbfp::bfp::{
    fp32_matmul, BfpContext, GuardAction, GuardPolicy, GuardStats, Rounding, TileSize,
};
use hbfp::coordinator::checkpoint::{Checkpoint, CheckpointStore, CkptError};
use hbfp::coordinator::config::LrSchedule;
use hbfp::coordinator::metrics::{RecoveryAction, RecoveryKind};
use hbfp::coordinator::resilient::{run_resilient, FaultTolerantModel, SoftmaxDemo};
use hbfp::coordinator::RunConfig;
use hbfp::runtime::HostTensor;
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};
use hbfp::util::pool::Pool;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hbfp_ft_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn demo_cfg(dir: &std::path::Path, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new("demo-centroids-hbfp8", steps)
        .with_seed(42)
        .with_lr(LrSchedule::Constant { lr: 0.5 })
        .with_checkpoint_every(5)
        .with_max_recoveries(4);
    cfg.checkpoint_dir = Some(dir.to_path_buf());
    cfg
}

/// The acceptance demo: a clean run writes rotating checkpoints; the
/// latest is then truncated on disk (a crash mid-write); the resumed run
/// falls back to `prev`, takes an injected NaN on its first step, rolls
/// back, widens 8 → 16 bits, and finishes with a clean history carrying
/// both recovery events.
#[test]
fn nan_plus_truncated_checkpoint_recovers_and_finishes() {
    let scenario = |tag: &str| -> (Vec<f32>, Vec<(RecoveryKind, RecoveryAction)>, u32) {
        let dir = tmp_dir(&format!("accept_{tag}"));

        // Phase 1: 10 clean steps -> latest at step 10, prev at step 5.
        let _clean = fault::install(FaultInjector::none());
        let cfg1 = demo_cfg(&dir, 10);
        let mut m1 = SoftmaxDemo::new(cfg1.seed, 8);
        let h1 = run_resilient(&mut m1, &cfg1).unwrap();
        assert_eq!(h1.steps.len(), 10);
        drop(_clean);

        // Crash mid-write: chop the tail off the latest checkpoint.
        let store = CheckpointStore::new(dir.clone(), "demo-centroids-hbfp8");
        let latest = store.latest_path();
        let bytes = std::fs::read(&latest).unwrap();
        std::fs::write(&latest, &bytes[..bytes.len() - 7]).unwrap();
        assert!(matches!(
            Checkpoint::load(&latest),
            Err(CkptError::Corrupt { .. })
        ));

        // Phase 2: resume (skipping the corrupt latest -> prev at step 5)
        // with a NaN activation injected at the narrow width class.
        let _nan = fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::NanActivation,
            rate: 1.0,
            seed: 3,
        }]));
        let cfg2 = demo_cfg(&dir, 20);
        let mut m2 = SoftmaxDemo::new(cfg2.seed, 8);
        let h2 = run_resilient(&mut m2, &cfg2).unwrap();

        assert_eq!(
            h2.steps.first().map(|s| s.step),
            Some(5),
            "must resume from the surviving prev checkpoint"
        );
        assert_eq!(h2.steps.last().map(|s| s.step), Some(19));
        assert!(!h2.diverged(), "the recovered history must be clean");
        let kinds: Vec<_> = h2.recoveries.iter().map(|r| (r.kind, r.action)).collect();
        assert!(
            kinds.contains(&(RecoveryKind::CorruptCheckpoint, RecoveryAction::RollbackWiden)),
            "the skipped corrupt latest must be recorded: {kinds:?}"
        );
        assert!(
            kinds.contains(&(RecoveryKind::NonFiniteLoss, RecoveryAction::RollbackWiden)),
            "the NaN hazard must be recorded: {kinds:?}"
        );
        assert!(m2.stats.fp32_fallbacks() >= 1, "guard must have degraded the NaN GEMM");

        let losses = h2.steps.iter().map(|s| s.loss).collect();
        let width = m2.width();
        let _ = std::fs::remove_dir_all(&dir);
        (losses, kinds, width)
    };

    let (l_a, k_a, w_a) = scenario("a");
    let (l_b, k_b, w_b) = scenario("b");
    assert!(l_a == l_b, "the whole recovery trajectory must be deterministic under a fixed seed");
    assert_eq!(k_a, k_b);
    assert_eq!((w_a, w_b), (16, 16), "one rollback widens 8 -> 16");
}

/// Corrupting BOTH checkpoints forces a restart-from-scratch recovery.
#[test]
fn all_checkpoints_corrupt_restarts_from_scratch() {
    let dir = tmp_dir("restart");
    {
        let _clean = fault::install(FaultInjector::none());
        let cfg = demo_cfg(&dir, 10);
        let mut m = SoftmaxDemo::new(cfg.seed, 8);
        run_resilient(&mut m, &cfg).unwrap();
    }
    let store = CheckpointStore::new(dir.clone(), "demo-centroids-hbfp8");
    for path in [store.latest_path(), store.prev_path()] {
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
    }
    let _nan = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::NanActivation,
        rate: 1.0,
        seed: 5,
    }]));
    let cfg = demo_cfg(&dir, 12);
    let mut m = SoftmaxDemo::new(cfg.seed, 8);
    let h = run_resilient(&mut m, &cfg).unwrap();
    // resume found no valid checkpoint (both corrupt) -> fresh start; the
    // NaN at step 0 then restarts again, widened.
    assert_eq!(h.steps.first().map(|s| s.step), Some(0));
    assert_eq!(h.steps.len(), 12);
    assert!(!h.diverged());
    assert!(h
        .recoveries
        .iter()
        .any(|r| r.kind == RecoveryKind::NonFiniteLoss && r.action == RecoveryAction::Restart));
    // both corrupt files were noticed during the rollback attempt
    assert!(h.recoveries.iter().filter(|r| r.kind == RecoveryKind::CorruptCheckpoint).count() >= 2);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Injected save-time truncation (the `ckpt-truncate` site) corrupts the
/// installed `latest`; the store's fallback still restores from `prev`.
#[test]
fn injected_truncation_on_save_falls_back_to_prev() {
    let dir = tmp_dir("trunc_save");
    let store = CheckpointStore::new(dir.clone(), "demo-centroids-hbfp8");
    let m = SoftmaxDemo::new(7, 8);
    let specs = m.specs();

    let _clean = fault::install(FaultInjector::none());
    let ck5 = Checkpoint { combo: "demo-centroids-hbfp8".into(), step: 5, leaves: m.state() };
    store.save(&ck5, &specs).unwrap();
    let ck10 = Checkpoint { combo: "demo-centroids-hbfp8".into(), step: 10, leaves: m.state() };
    store.save(&ck10, &specs).unwrap(); // rotates ck5 -> prev
    drop(_clean);

    let _trunc = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::CkptTruncate,
        rate: 1.0,
        seed: 1,
    }]));
    let ck15 = Checkpoint { combo: "demo-centroids-hbfp8".into(), step: 15, leaves: m.state() };
    store.save(&ck15, &specs).unwrap(); // written truncated; ck10 -> prev
    drop(_trunc);

    let _clean = fault::install(FaultInjector::none());
    assert!(Checkpoint::load(&store.latest_path()).is_err(), "latest must be the corrupt ck15");
    let (ck, path) = store
        .load_newest_valid("demo-centroids-hbfp8", &specs)
        .unwrap()
        .expect("prev must survive");
    assert_eq!(ck.step, 10);
    assert_eq!(path, store.prev_path());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Garbled checkpoint bytes (the `ckpt-garble` site) are caught by the
/// CRC on load — typed corruption, never a panic or garbage tensors.
#[test]
fn injected_garble_is_caught_by_crc() {
    let dir = tmp_dir("garble");
    let m = SoftmaxDemo::new(9, 8);
    let specs = m.specs();
    let path = dir.join("garbled.ckpt");
    let ck = Checkpoint { combo: "demo-centroids-hbfp8".into(), step: 3, leaves: m.state() };
    {
        let _garble = fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::CkptGarble,
            rate: 1.0,
            seed: 2,
        }]));
        ck.save(&path, &specs).unwrap();
    }
    match Checkpoint::load(&path) {
        Err(e) => assert!(e.is_recoverable_corruption(), "unexpected error class: {e}"),
        Ok(_) => panic!("garbled checkpoint must not load"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A worker panic fails only the dispatching call (typed error), the pool
/// survives, and a redispatch of the identical work is bit-identical to a
/// never-faulted pool.
#[test]
fn worker_panic_contained_and_redispatch_bit_identical() {
    let jobs = || (0..64usize).map(|i| (i, i as u64)).collect::<Vec<_>>();
    let work = |i: usize, v: u64, out: &mut [u64]| {
        // per-slot writes: disjoint, lane-order independent
        out[i] = v.wrapping_mul(0x9e37_79b9).rotate_left(7);
    };

    let pool = Pool::new(3);
    {
        let _panic = fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::WorkerPanic,
            rate: 1.0,
            seed: 4,
        }]));
        let out = std::sync::Mutex::new(vec![0u64; 64]);
        let err = pool
            .try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap()))
            .unwrap_err();
        assert!(err.message().contains("injected worker panic"), "{err}");
    }

    // injector restored -> the same pool must serve the same work again
    let _clean = fault::install(FaultInjector::none());
    let out = std::sync::Mutex::new(vec![0u64; 64]);
    pool.try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap())).unwrap();
    let survived = out.into_inner().unwrap();

    let fresh_pool = Pool::new(3);
    let out = std::sync::Mutex::new(vec![0u64; 64]);
    fresh_pool.try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap())).unwrap();
    let fresh = out.into_inner().unwrap();
    assert!(survived == fresh, "post-recovery dispatch must be bit-identical");
}

/// N consecutive injected panics against the same pool: every dispatch
/// fails with a contained error, the worker set stays serviceable
/// throughout, and the first clean redispatch is bit-identical to a
/// fresh pool's answer.
#[test]
fn pool_try_run_heals_after_repeated_panics() {
    let jobs = || (0..48usize).map(|i| (i, i as u64)).collect::<Vec<_>>();
    let work = |i: usize, v: u64, out: &mut [u64]| {
        out[i] = v.wrapping_mul(0x517c_c1b7).rotate_left(11);
    };

    let pool = Pool::new(3);
    let baseline = {
        let _clean = fault::install(FaultInjector::none());
        let out = std::sync::Mutex::new(vec![0u64; 48]);
        pool.try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap())).unwrap();
        out.into_inner().unwrap()
    };

    {
        let _panic = fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::WorkerPanic,
            rate: 1.0,
            seed: 9,
        }]));
        for round in 0..5 {
            let out = std::sync::Mutex::new(vec![0u64; 48]);
            let err = pool
                .try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap()))
                .unwrap_err();
            assert!(
                err.message().contains("injected worker panic"),
                "round {round}: unexpected panic payload {err}"
            );
        }
    }

    // after five faulted dispatches, the same pool answers bit-identically
    let _clean = fault::install(FaultInjector::none());
    let out = std::sync::Mutex::new(vec![0u64; 48]);
    pool.try_run(jobs(), 4, |i, v| work(i, v, &mut out.lock().unwrap())).unwrap();
    assert!(
        out.into_inner().unwrap() == baseline,
        "healed pool must redispatch bit-identically"
    );
}

/// The slow-worker site only delays; results are unchanged.
#[test]
fn slow_worker_changes_no_bits() {
    let _slow = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::SlowWorker,
        rate: 1.0,
        seed: 6,
    }]));
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
    let (m, k, n) = (12, 24, 16);
    let a: Vec<f32> = (0..m * k).map(|i| ((i * 31 % 97) as f32) / 13.0 - 3.5).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i * 17 % 89) as f32) / 11.0 - 4.0).collect();
    let slow = ctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
    drop(_slow);
    let _clean = fault::install(FaultInjector::none());
    let fast = ctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
    assert!(slow == fast);
}

/// Guarded GEMM under an injected NaN activation: FP32 fallback result is
/// the IEEE product, and the stats counters show the degradation.
#[test]
fn guarded_gemm_degrades_injected_nan_to_fp32() {
    let _clean = fault::install(FaultInjector::none());
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8)).with_guard(GuardPolicy {
        action: GuardAction::Fp32Fallback,
        ..GuardPolicy::default()
    });
    let (m, k, n) = (6, 16, 8);
    let mut a: Vec<f32> = (0..m * k).map(|i| (i as f32).sin()).collect();
    a[37] = f32::NAN; // what the nan-activation site does to a batch
    let b: Vec<f32> = (0..k * n).map(|i| (i as f32).cos()).collect();
    let mut r = hbfp::util::rng::Xorshift32::new(1);
    let qb = ctx.quantize(&b, k, n, 8, &mut Rounding::Stochastic(&mut r)).unwrap();
    let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
    let stats = GuardStats::new();
    let mut out = vec![0.0f32; m * n];
    let outcome = plan
        .quantize_execute_guarded(&a, &mut Rounding::NearestEven, &qb, &mut out, Some(&stats))
        .unwrap();
    assert!(outcome.tripped && outcome.fell_back_fp32);
    assert_eq!(stats.nonfinite_inputs(), 1);
    assert_eq!(stats.fp32_fallbacks(), 1);
    let want = fp32_matmul(&a, &qb.to_f32(), m, k, n);
    assert!(out == want);
    assert!(out.iter().any(|v| v.is_nan()), "the NaN flows to the output under IEEE rules");
}

/// CI fault-matrix entry point: run the resilient demo under whatever
/// `HBFP_FAULT` the environment configured. The contract is graceful
/// behaviour under every site: the loop either completes with a clean
/// history or fails with a typed error — it never panics, and any
/// completed history is finite.
#[test]
fn demo_survives_environment_faults() {
    let _env = fault::exclusive(); // run under HBFP_FAULT, serialized with install()ers
    let dir = tmp_dir("env");
    let cfg = demo_cfg(&dir, 15);
    let mut model = SoftmaxDemo::new(cfg.seed, 8);
    match run_resilient(&mut model, &cfg) {
        Ok(h) => {
            assert!(!h.diverged(), "a completed recovered history must be clean");
            assert_eq!(h.steps.last().map(|s| s.step), Some(14));
            if fault::active().armed() {
                for r in &h.recoveries {
                    assert!(!r.detail.is_empty());
                }
            } else {
                assert!(h.recoveries.is_empty(), "no faults -> no interventions");
            }
        }
        Err(e) => {
            // budget exhaustion under heavy fault rates is a legitimate,
            // typed outcome — but only when faults are actually armed.
            assert!(fault::active().armed(), "clean environment must not fail: {e:#}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
