//! Contract tests for the `BfpContext` + `MatmulPlan` execution API: every
//! policy configuration must be bit-identical to the always-i64
//! j-innermost `bfp_matmul_naive` reference — across rounding modes,
//! thread counts, every detected SIMD family, both kernel layouts, both
//! dispatch backends, and ragged shapes that exercise panel padding. A
//! plan reused across calls must be deterministic, `execute_into` must
//! honor the caller's buffer, and the `#[deprecated]` shims over the old
//! free-function zoo must stay bit-equal to their context counterparts
//! (this file's final module is the one place in the repo allowed to
//! call them).

use hbfp::bfp::{
    bfp_matmul_naive, kernels, AccPolicy, BfpContext, BfpTensor, MatmulKernel, Rounding, TileSize,
};
use hbfp::util::pool::ParBackend;
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() * scale).collect()
}

/// Ragged shapes: nothing divides the 16/32-wide vector panels, edge
/// tiles in every dimension, single rows/cols, k spanning tiles.
const SHAPES: &[(usize, usize, usize)] =
    &[(17, 23, 19), (48, 48, 48), (5, 64, 30), (1, 1, 1), (3, 129, 33), (40, 100, 3)];

#[test]
fn plan_execute_matches_naive_across_rounding_threads_and_isas() {
    // The acceptance matrix: {RNE, stochastic} x {1, 4 threads} x every
    // detected ISA x ragged shapes, plan execution vs the naive
    // reference, bit for bit.
    let mut rng = SplitMix64::new(0x51AD);
    for &(m, k, n) in SHAPES {
        let a = rand_mat(&mut rng, m * k, 2.0);
        let b = rand_mat(&mut rng, k * n, 0.5);
        for &tile in &[TileSize::Whole, TileSize::Edge(4), TileSize::Edge(24)] {
            for &(ma, mb) in &[(8u32, 8u32), (12, 12), (16, 16), (8, 16), (20, 20)] {
                for stochastic in [false, true] {
                    let base = BfpContext::from_env().with_tile(tile);
                    let (qa, qb) = if stochastic {
                        let mut ra = Xorshift32::new(0xAA);
                        let mut rb = Xorshift32::new(0xBB);
                        (
                            base.quantize(&a, m, k, ma, &mut Rounding::Stochastic(&mut ra))
                                .unwrap(),
                            base.quantize(&b, k, n, mb, &mut Rounding::Stochastic(&mut rb))
                                .unwrap(),
                        )
                    } else {
                        (
                            base.quantize(&a, m, k, ma, &mut Rounding::NearestEven).unwrap(),
                            base.quantize(&b, k, n, mb, &mut Rounding::NearestEven).unwrap(),
                        )
                    };
                    let naive = bfp_matmul_naive(&qa, &qb).unwrap();
                    for &isa in &kernels::detected() {
                        for threads in [1usize, 4] {
                            let ctx = base.clone().with_isa(isa).with_threads(threads);
                            let plan = ctx.plan_matmul(m, k, n, (ma, mb)).unwrap();
                            let got = plan.execute(&qa, &qb).unwrap();
                            assert!(
                                got == naive,
                                "plan diverged: isa={isa:?} threads={threads} ma={ma} mb={mb} \
                                 tile={tile:?} stochastic={stochastic} ({m}x{k}x{n})"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn fused_plan_matches_materialized_across_isas_and_threads() {
    // quantize_execute must equal quantize-then-execute draw for draw —
    // the stochastic per-tile substreams are part of the contract.
    let mut rng = SplitMix64::new(0xFEED);
    for &(m, k, n) in &[(17usize, 23usize, 19usize), (5, 64, 30), (40, 100, 3)] {
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        for &tile in &[TileSize::Whole, TileSize::Edge(24)] {
            let base = BfpContext::from_env().with_tile(tile);
            let qb = base.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();
            for &isa in &kernels::detected() {
                for threads in [1usize, 4] {
                    let ctx = base.clone().with_isa(isa).with_threads(threads);
                    let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
                    let mut r1 = Xorshift32::new(0x51);
                    let mut r2 = Xorshift32::new(0x51);
                    let qa = ctx.quantize(&a, m, k, 8, &mut Rounding::Stochastic(&mut r1)).unwrap();
                    let want = plan.execute(&qa, &qb).unwrap();
                    let got =
                        plan.quantize_execute(&a, &mut Rounding::Stochastic(&mut r2), &qb).unwrap();
                    assert!(
                        got == want,
                        "fused != materialized: isa={isa:?} threads={threads} tile={tile:?} \
                         ({m}x{k}x{n})"
                    );
                }
            }
        }
    }
}

#[test]
fn plan_reuse_across_calls_is_deterministic() {
    // One plan, many executions, interleaved execute / execute_into /
    // quantize_execute_into: every call must reproduce the same bits
    // (the resident-weight training-step contract).
    let mut rng = SplitMix64::new(0x9E15E);
    let (m, k, n) = (24, 56, 40);
    let a = rand_mat(&mut rng, m * k, 1.5);
    let b = rand_mat(&mut rng, k * n, 0.8);
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(24));
    let qa = ctx.quantize(&a, m, k, 8, &mut Rounding::NearestEven).unwrap();
    let qb = ctx.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();
    let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
    let reference = plan.execute(&qa, &qb).unwrap();
    let fused_ref = plan.quantize_execute(&a, &mut Rounding::NearestEven, &qb).unwrap();
    let mut out = vec![0.0f32; plan.out_len()];
    for round in 0..8 {
        plan.execute_into(&qa, &qb, &mut out).unwrap();
        assert!(out == reference, "execute_into round {round} diverged");
        assert!(plan.execute(&qa, &qb).unwrap() == reference, "execute round {round} diverged");
        plan.quantize_execute_into(&a, &mut Rounding::NearestEven, &qb, &mut out).unwrap();
        assert!(out == fused_ref, "fused round {round} diverged");
    }
    // the one-shot buffered convenience rides the same machinery
    ctx.matmul_into(&qa, &qb, &mut out).unwrap();
    assert!(out == reference, "ctx.matmul_into diverged from the plan path");
}

#[test]
fn policy_knobs_never_change_bits() {
    // Kernel layout, dispatch backend, and the accumulator override are
    // speed knobs only.
    let mut rng = SplitMix64::new(0x0DD5);
    let (m, k, n) = (33, 47, 29);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let base = BfpContext::from_env().with_tile(TileSize::Edge(8));
    for &(ma, mb) in &[(8u32, 8u32), (12, 12), (16, 8)] {
        let qa = base.quantize(&a, m, k, ma, &mut Rounding::NearestEven).unwrap();
        let qb = base.quantize(&b, k, n, mb, &mut Rounding::NearestEven).unwrap();
        let naive = bfp_matmul_naive(&qa, &qb).unwrap();
        for kernel in [MatmulKernel::Packed, MatmulKernel::RowMajor] {
            for backend in [ParBackend::Pooled, ParBackend::Scoped] {
                for acc in [AccPolicy::Auto, AccPolicy::ForceI64] {
                    let ctx = base
                        .clone()
                        .with_kernel(kernel)
                        .with_backend(backend)
                        .with_acc(acc)
                        .with_threads(4);
                    let got = ctx.matmul(&qa, &qb).unwrap();
                    assert!(
                        got == naive,
                        "{kernel:?}/{backend:?}/{acc:?} diverged at ma={ma} mb={mb}"
                    );
                }
            }
        }
    }
}

// (Clamping of unsupported Isa requests — including the whole-matmul
// differential — is covered once, in tests/simd_kernels.rs; the builder
// clamp itself is unit-tested in bfp::context.)

#[test]
fn context_quantize_matches_from_f32() {
    // ctx.quantize is the context-mediated converter: same tile, same
    // bits as the plain constructor, for both rounding modes.
    let mut rng = SplitMix64::new(0x0BF);
    let (rows, cols) = (40, 36);
    let data = rand_mat(&mut rng, rows * cols, 1.5);
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(16));
    let a = ctx.quantize(&data, rows, cols, 8, &mut Rounding::NearestEven).unwrap();
    let b =
        BfpTensor::from_f32(&data, rows, cols, 8, TileSize::Edge(16), &mut Rounding::NearestEven)
            .unwrap();
    assert!(a.mantissas == b.mantissas && a.exponents == b.exponents);

    let mut r1 = Xorshift32::new(0x7E57);
    let mut r2 = Xorshift32::new(0x7E57);
    let sa = ctx.quantize(&data, rows, cols, 8, &mut Rounding::Stochastic(&mut r1)).unwrap();
    let sb = BfpTensor::from_f32(
        &data,
        rows,
        cols,
        8,
        TileSize::Edge(16),
        &mut Rounding::Stochastic(&mut r2),
    )
    .unwrap();
    assert!(sa.mantissas == sb.mantissas && sa.exponents == sb.exponents);
    // and the caller RNGs advanced identically (exactly one draw)
    assert_eq!(r1.next_u32(), r2.next_u32());
}

/// The deprecation-shim equivalence pass: the retired free functions
/// must remain exact aliases of their context counterparts until they
/// are deleted. This module is the single place in the repository that
/// may call them.
#[allow(deprecated)]
mod shim_equivalence {
    use super::*;
    use hbfp::bfp::matmul::{
        bfp_matmul, bfp_matmul_rowmajor, bfp_matmul_rowmajor_with_threads,
        bfp_matmul_with_backend, bfp_matmul_with_simd, bfp_matmul_with_threads, hbfp_matmul_f32,
        quantize_matmul, quantize_matmul_with_threads,
    };

    #[test]
    fn all_nine_shims_match_their_context_counterparts() {
        let mut rng = SplitMix64::new(0x5111);
        let (m, k, n) = (19, 37, 23);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let tile = TileSize::Edge(8);
        let ctx = BfpContext::from_env().with_tile(tile);
        let qa = ctx.quantize(&a, m, k, 8, &mut Rounding::NearestEven).unwrap();
        let qb = ctx.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();

        // 1. bfp_matmul
        assert!(bfp_matmul(&qa, &qb).unwrap() == ctx.matmul(&qa, &qb).unwrap());
        // 2. bfp_matmul_with_threads
        assert!(
            bfp_matmul_with_threads(&qa, &qb, 2).unwrap()
                == ctx.clone().with_threads(2).matmul(&qa, &qb).unwrap()
        );
        // 3. bfp_matmul_with_backend
        assert!(
            bfp_matmul_with_backend(&qa, &qb, 2, ParBackend::Scoped).unwrap()
                == ctx
                    .clone()
                    .with_threads(2)
                    .with_backend(ParBackend::Scoped)
                    .matmul(&qa, &qb)
                    .unwrap()
        );
        // 4. bfp_matmul_with_simd
        for &isa in &kernels::detected() {
            assert!(
                bfp_matmul_with_simd(&qa, &qb, 2, isa).unwrap()
                    == ctx.clone().with_threads(2).with_isa(isa).matmul(&qa, &qb).unwrap()
            );
        }
        // 5. bfp_matmul_rowmajor
        let rm = ctx.clone().with_kernel(MatmulKernel::RowMajor);
        assert!(bfp_matmul_rowmajor(&qa, &qb).unwrap() == rm.matmul(&qa, &qb).unwrap());
        // 6. bfp_matmul_rowmajor_with_threads
        assert!(
            bfp_matmul_rowmajor_with_threads(&qa, &qb, 3).unwrap()
                == rm.clone().with_threads(3).matmul(&qa, &qb).unwrap()
        );
        // 7. quantize_matmul (stochastic: shims must preserve draw order)
        let mut r1 = Xorshift32::new(0x99);
        let mut r2 = Xorshift32::new(0x99);
        assert!(
            quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r1), &qb).unwrap()
                == ctx
                    .quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r2), &qb)
                    .unwrap()
        );
        assert_eq!(r1.next_u32(), r2.next_u32(), "shims must consume identical draws");
        // 8. quantize_matmul_with_threads
        let mut r1 = Xorshift32::new(0x77);
        let mut r2 = Xorshift32::new(0x77);
        assert!(
            quantize_matmul_with_threads(&a, m, 8, &mut Rounding::Stochastic(&mut r1), &qb, 2)
                .unwrap()
                == ctx
                    .clone()
                    .with_threads(2)
                    .quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r2), &qb)
                    .unwrap()
        );
        // 9. hbfp_matmul_f32
        assert!(
            hbfp_matmul_f32(&a, &b, m, k, n, 8, tile).unwrap()
                == ctx.matmul_f32(&a, &b, m, k, n, 8).unwrap()
        );
    }

    #[test]
    fn from_f32_with_threads_shim_matches_context_quantize() {
        let mut rng = SplitMix64::new(0x10CA1);
        let (rows, cols) = (30, 22);
        let data = rand_mat(&mut rng, rows * cols, 1.0);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8)).with_threads(2);
        let mut r1 = Xorshift32::new(0xF00);
        let mut r2 = Xorshift32::new(0xF00);
        let shim = BfpTensor::from_f32_with_threads(
            &data,
            rows,
            cols,
            8,
            TileSize::Edge(8),
            &mut Rounding::Stochastic(&mut r1),
            2,
        )
        .unwrap();
        let ctxed = ctx.quantize(&data, rows, cols, 8, &mut Rounding::Stochastic(&mut r2)).unwrap();
        assert!(shim.mantissas == ctxed.mantissas && shim.exponents == ctxed.exponents);
    }
}
