//! Observability-layer integration (the determinism satellite):
//!
//! (a) `HBFP_OBS=off` leaves the trainer's metrics JSON byte-identical
//!     to a pre-obs build — no `"obs"` key, no extra fields;
//! (b) training curves are bitwise identical with observability fully
//!     on vs fully off (probes never touch RNG draws or GEMM bits);
//! (c) per-layer numeric-health timelines and span sequences are
//!     invariant across `HBFP_THREADS=1` vs `4` once wall-clock fields
//!     are stripped (health depends only on tensor values; spans are
//!     recorded on the control thread);
//! (d) full-mode exports carry the schema the CI smoke greps for, and
//!     the datapath counters are conserved (blocks >= tensors >= 0,
//!     GEMMs grow monotonically while counting).
//!
//! Every test installs an obs mode, which serializes them on the
//! install lock and shields them from an ambient `HBFP_OBS`.

use hbfp::bfp::context::{OBS_GEMMS_EXECUTED, OBS_TENSORS_QUANTIZED};
use hbfp::bfp::quant::OBS_BLOCKS_QUANTIZED;
use hbfp::bfp::BfpContext;
use hbfp::coordinator::{LrSchedule, RunConfig};
use hbfp::nn::Trainer;
use hbfp::obs::{self, trace, ObsMode};
use hbfp::util::fault::{self, FaultInjector};
use hbfp::util::json::Json;

use std::sync::atomic::Ordering;

fn cfg(steps: usize) -> RunConfig {
    RunConfig::new("mlp-tinyimg-hbfp8_t8", steps)
        .with_seed(5)
        .with_lr(LrSchedule::Constant { lr: 0.02 })
}

fn run_with_threads(threads: usize, steps: usize) -> hbfp::nn::NnRunReport {
    let trainer = Trainer::with_context(BfpContext::from_env().with_threads(threads));
    trainer.run(&cfg(steps)).unwrap()
}

/// Strip the wall-clock stage-timing sections from an `"obs"` export,
/// leaving only the value-dependent (and therefore run-invariant)
/// numeric-health timelines.
fn strip_timings(obs: &Json) -> Json {
    match obs {
        Json::Obj(m) => {
            let mut m = m.clone();
            m.remove("stage_us");
            m.remove("stage_totals_us");
            Json::Obj(m)
        }
        other => other.clone(),
    }
}

// ---------------------------------------------------------------- (a) --

#[test]
fn off_mode_omits_the_obs_section_entirely() {
    let _f = fault::install(FaultInjector::none());
    {
        let _o = obs::install(ObsMode::Off);
        let r = run_with_threads(1, 4);
        assert!(r.obs.is_none(), "off mode must not collect");
        let j = r.summary_json();
        assert!(j.get("obs").is_none(), "off-mode summary JSON must carry no obs key");
    }
    // counters mode collects counters but still no per-layer timeline
    {
        let _o = obs::install(ObsMode::Counters);
        let r = run_with_threads(1, 4);
        assert!(r.obs.is_none(), "counters mode records totals, not timelines");
    }
}

// ---------------------------------------------------------------- (b) --

#[test]
fn curves_are_bit_identical_with_obs_full_vs_off() {
    let _f = fault::install(FaultInjector::none());
    let steps = 30;
    let off = {
        let _o = obs::install(ObsMode::Off);
        run_with_threads(1, steps)
    };
    let full = {
        let _o = obs::install(ObsMode::Full);
        run_with_threads(1, steps)
    };
    let c_off: Vec<u32> = off.history.steps.iter().map(|s| s.loss.to_bits()).collect();
    let c_full: Vec<u32> = full.history.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(c_off, c_full, "probes must not perturb a single bit of the curve");
    assert!(off.obs.is_none() && full.obs.is_some());
}

// ---------------------------------------------------------------- (c) --

#[test]
fn health_timelines_and_spans_are_thread_count_invariant() {
    let _f = fault::install(FaultInjector::none());
    let _o = obs::install(ObsMode::Full);
    let steps = 20;

    trace::clear();
    let r1 = run_with_threads(1, steps);
    let spans1: Vec<(&str, u32)> =
        trace::snapshot().0.iter().map(|e| (e.name, e.depth)).collect();

    trace::clear();
    let r4 = run_with_threads(4, steps);
    let spans4: Vec<(&str, u32)> =
        trace::snapshot().0.iter().map(|e| (e.name, e.depth)).collect();

    let h1 = strip_timings(r1.obs.as_ref().unwrap()).to_string();
    let h4 = strip_timings(r4.obs.as_ref().unwrap()).to_string();
    assert_eq!(h1, h4, "health timelines depend on tensor values, not thread count");

    assert!(!spans1.is_empty(), "full mode records spans");
    assert_eq!(spans1, spans4, "span (name, depth) sequence is thread-count invariant");

    // the loss curves also stay bitwise identical (the repo-wide contract)
    let c1: Vec<u32> = r1.history.steps.iter().map(|s| s.loss.to_bits()).collect();
    let c4: Vec<u32> = r4.history.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(c1, c4);
}

// ---------------------------------------------------------------- (d) --

#[test]
fn full_mode_export_carries_the_smoke_schema() {
    let _f = fault::install(FaultInjector::none());
    let _o = obs::install(ObsMode::Full);
    let r = run_with_threads(1, 8);
    let obs_json = r.obs.as_ref().expect("full mode collects");

    let health = obs_json.get("health").expect("per-layer health section");
    let layers = match health {
        Json::Obj(m) => m,
        other => panic!("health must be an object, got {other:?}"),
    };
    assert!(!layers.is_empty(), "at least one named layer probed");
    for (layer, rows) in layers {
        let rows = rows.as_arr().unwrap_or_else(|| panic!("{layer}: timeline is an array"));
        assert!(!rows.is_empty(), "{layer}: timeline non-empty");
        for row in rows {
            for key in
                ["step", "exp_min", "exp_max", "exp_span", "clamp_frac", "sat_frac", "snr_db"]
            {
                assert!(row.get(key).is_some(), "{layer}: row missing {key}");
            }
            let snr = row.get("snr_db").unwrap().as_f64().unwrap();
            assert!(snr.is_finite(), "{layer}: SNR must be finite, got {snr}");
            let clamp = row.get("clamp_frac").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&clamp), "{layer}: clamp_frac {clamp}");
        }
    }

    let totals = obs_json.get("stage_totals_us").expect("stage totals");
    for stage in ["quantize", "gemm", "fwd", "bwd", "opt"] {
        assert!(totals.get(stage).is_some(), "stage_totals_us missing {stage}");
    }
    let stage_rows = obs_json.get("stage_us").unwrap().as_arr().unwrap();
    assert!(!stage_rows.is_empty(), "per-step stage rows recorded");

    // summary JSON surfaces the same section under "obs"
    let j = r.summary_json();
    assert!(j.get("obs").and_then(|o| o.get("health")).is_some());
}

#[test]
fn datapath_counters_are_conserved_while_counting() {
    let _f = fault::install(FaultInjector::none());
    let _o = obs::install(ObsMode::Counters);
    let blocks0 = OBS_BLOCKS_QUANTIZED.load(Ordering::Relaxed);
    let tensors0 = OBS_TENSORS_QUANTIZED.load(Ordering::Relaxed);
    let gemms0 = OBS_GEMMS_EXECUTED.load(Ordering::Relaxed);

    let r = run_with_threads(1, 6);
    assert!(!r.history.diverged());

    let blocks = OBS_BLOCKS_QUANTIZED.load(Ordering::Relaxed) - blocks0;
    let tensors = OBS_TENSORS_QUANTIZED.load(Ordering::Relaxed) - tensors0;
    let gemms = OBS_GEMMS_EXECUTED.load(Ordering::Relaxed) - gemms0;
    assert!(gemms > 0, "an HBFP run executes GEMMs");
    assert!(tensors > 0, "weights quantize through BfpContext::quantize");
    assert!(blocks >= tensors, "every tensor quantizes at least one block");

    // the registry export mirrors the same three counters
    let reg = hbfp::obs::Registry::new();
    hbfp::bfp::export_datapath_counters(&reg);
    let j = reg.to_json();
    let bfp = j.get("bfp").expect("bfp section");
    for key in ["blocks_quantized", "tensors_quantized", "gemms_executed"] {
        assert!(bfp.get(key).is_some(), "registry missing bfp.{key}");
    }
}
