//! Runtime integration: load real AOT artifacts, verify the manifest
//! contract holds at the PJRT boundary — input arity, *untupled* output
//! arity (the assumption the whole state-feedback design rests on),
//! init determinism, and numeric sanity of a train step.
//!
//! Requires `make artifacts` (skips loudly otherwise).

use std::path::Path;
use std::sync::Arc;

use hbfp::runtime::{fetch_f32, fetch_scalar_f32, Engine, HostTensor, Manifest, Role};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(Arc::new(m)),
        Err(e) => {
            eprintln!("SKIP runtime_integration: {e:#} — run `make artifacts`");
            None
        }
    }
}

const COMBO: &str = "mlp-cifar10like-fp32";

#[test]
fn init_outputs_match_manifest_and_are_deterministic() {
    let Some(m) = manifest() else { return };
    let engine = Engine::new().unwrap();
    let art = m.artifact(COMBO, Role::Init).unwrap();
    let prog = engine.load(art).unwrap();

    let out1 = prog.run_host(&[HostTensor::scalar_i32(7)]).unwrap();
    // The untupling contract: one PJRT buffer per manifest output.
    assert_eq!(out1.len(), art.outputs.len());
    assert_eq!(out1.len(), art.state_len);

    let out2 = prog.run_host(&[HostTensor::scalar_i32(7)]).unwrap();
    let out3 = prog.run_host(&[HostTensor::scalar_i32(8)]).unwrap();
    // Compare the concatenation of all leaves (individual leaves may be
    // legitimately zero — biases, momentum).
    let cat = |outs: &[xla::Literal]| -> Vec<f32> {
        outs.iter().flat_map(|l| fetch_f32(l).unwrap()).collect()
    };
    let (v1, v2, v3) = (cat(&out1), cat(&out2), cat(&out3));
    assert_eq!(v1, v2, "same seed must give identical init");
    assert_ne!(v1, v3, "different seeds must differ");
    // He-normal init: finite, non-degenerate
    assert!(v1.iter().all(|x| x.is_finite()));
    assert!(v1.iter().any(|&x| x != 0.0));
}

#[test]
fn train_step_roundtrip_decreases_loss() {
    let Some(m) = manifest() else { return };
    let engine = Engine::new().unwrap();
    let init = engine.load(m.artifact(COMBO, Role::Init).unwrap()).unwrap();
    let train_art = m.artifact(COMBO, Role::Train).unwrap();
    let train = engine.load(train_art).unwrap();

    let mut state = init.run_host(&[HostTensor::scalar_i32(0)]).unwrap();
    // fixed batch: one distinct image per class-ish (random but fixed)
    let n = train_art.batch;
    let spec = &train_art.inputs[train_art.state_len];
    let elems: usize = spec.shape.iter().product();
    let x: Vec<f32> = (0..elems).map(|i| ((i * 2654435761) % 1000) as f32 / 500.0 - 1.0).collect();
    let y: Vec<i32> = (0..n as i32).map(|i| i % 10).collect();
    let xb = HostTensor::F32(x, spec.shape.clone()).to_literal().unwrap();
    let yb = HostTensor::I32(y, vec![n]).to_literal().unwrap();
    let lr = HostTensor::scalar_f32(0.1).to_literal().unwrap();

    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..30 {
        let mut args: Vec<&xla::Literal> = state.iter().collect();
        args.push(&xb);
        args.push(&yb);
        args.push(&lr);
        let mut out = train.run(&args).unwrap();
        assert_eq!(out.len(), train_art.outputs.len(), "untupling contract (train)");
        let acc = out.pop().unwrap();
        let loss = fetch_scalar_f32(&out.pop().unwrap()).unwrap();
        let _ = fetch_scalar_f32(&acc).unwrap();
        state = out;
        first_loss.get_or_insert(loss);
        last_loss = loss;
        assert!(loss.is_finite(), "loss must stay finite");
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.5,
        "overfitting one batch must collapse the loss: {first} -> {last_loss}"
    );
}

#[test]
fn eval_step_returns_metrics() {
    let Some(m) = manifest() else { return };
    let engine = Engine::new().unwrap();
    let init = engine.load(m.artifact(COMBO, Role::Init).unwrap()).unwrap();
    let eval_art = m.artifact(COMBO, Role::Eval).unwrap();
    let eval = engine.load(eval_art).unwrap();

    let state = init.run_host(&[HostTensor::scalar_i32(0)]).unwrap();
    let n = eval_art.batch;
    let spec = &eval_art.inputs[eval_art.state_len];
    let elems: usize = spec.shape.iter().product();
    let xb = HostTensor::F32(vec![0.1; elems], spec.shape.clone()).to_literal().unwrap();
    let yb = HostTensor::I32(vec![0; n], vec![n]).to_literal().unwrap();
    let mut args: Vec<&xla::Literal> = state.iter().collect();
    args.push(&xb);
    args.push(&yb);
    let out = eval.run(&args).unwrap();
    assert_eq!(out.len(), 2);
    let loss_sum = fetch_scalar_f32(&out[0]).unwrap();
    let correct = fetch_scalar_f32(&out[1]).unwrap();
    // untrained model on 10 classes: loss near ln(10) per example
    assert!(loss_sum > 0.0 && loss_sum.is_finite());
    assert!((0.0..=n as f32).contains(&correct));
}

#[test]
fn pallas_artifact_loads_and_runs() {
    // The L1-bearing path: hbfpp8 artifacts contain the lowered Pallas
    // kernel (grid while-loop). Compiling + stepping it proves the full
    // L1 -> L2 -> L3 composition.
    let Some(m) = manifest() else { return };
    let engine = Engine::new().unwrap();
    let combo = "mlp-cifar10like-hbfpp8_16_t24";
    let init = engine.load(m.artifact(combo, Role::Init).unwrap()).unwrap();
    let train_art = m.artifact(combo, Role::Train).unwrap();
    let train = engine.load(train_art).unwrap();
    let state = init.run_host(&[HostTensor::scalar_i32(1)]).unwrap();
    let n = train_art.batch;
    let spec = &train_art.inputs[train_art.state_len];
    let elems: usize = spec.shape.iter().product();
    let xb = HostTensor::F32(vec![0.5; elems], spec.shape.clone()).to_literal().unwrap();
    let yb = HostTensor::I32(vec![1; n], vec![n]).to_literal().unwrap();
    let lr = HostTensor::scalar_f32(0.05).to_literal().unwrap();
    let mut args: Vec<&xla::Literal> = state.iter().collect();
    args.push(&xb);
    args.push(&yb);
    args.push(&lr);
    let out = train.run(&args).unwrap();
    let loss = fetch_scalar_f32(&out[out.len() - 2]).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "pallas-path loss {loss}");
}

#[test]
fn manifest_covers_all_experiment_combos() {
    let Some(m) = manifest() else { return };
    let combos = m.combos();
    assert!(combos.len() >= 40, "expected >= 40 combos, got {}", combos.len());
    for needed in [
        "resnet_mini-cifar10like-fp_m2_e8",
        "wrn_mini-cifar100like-hbfp8_16_tnone",
        "lstm-ptblike-hbfp12_16_t24",
        "resnet_mini-imagenetlike-hbfp8_16_t24",
    ] {
        assert!(
            combos.iter().any(|c| c == needed),
            "missing combo {needed} (run `make artifacts`)"
        );
    }
}
