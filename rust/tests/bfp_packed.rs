//! Packed-kernel contract tests: the width-packed, multi-threaded,
//! accumulator-width-selecting BFP matmul (driven through the
//! context/plan API) must be bit-for-bit equal to the retained
//! `bfp_matmul_naive` reference (j-innermost, always-i64) across storage
//! classes, tile sizes, mixed operand widths, adversarial worst-case
//! mantissas at the i32-overflow boundary, and any thread count — and
//! the fused convert+matmul must equal materialize-then-multiply
//! exactly, stochastic rounding included.

use hbfp::bfp::{
    acc_fits_i32, bfp_matmul_naive, BfpContext, BfpTensor, Mantissas, Rounding, TileSize,
};
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn ctx() -> BfpContext {
    BfpContext::from_env()
}

fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() * scale).collect()
}

/// Random mantissas spanning the full two's-complement range of `bits`.
fn rand_mantissas(rng: &mut SplitMix64, len: usize, bits: u32) -> Vec<i32> {
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    (0..len).map(|_| (lo + (rng.next_u64() % (hi - lo + 1) as u64) as i64) as i32).collect()
}

fn pack(bits: u32, q: &[i32]) -> Mantissas {
    let mut m = Mantissas::for_width(bits, q.len());
    for (i, &v) in q.iter().enumerate() {
        m.set(i, v);
    }
    m
}

#[test]
fn packed_matches_naive_across_widths_and_tiles() {
    let mut rng = SplitMix64::new(0xD1FF);
    for &(m, k, n) in &[(17usize, 23usize, 19usize), (48, 48, 48), (40, 64, 24)] {
        let a = rand_mat(&mut rng, m * k, 2.0);
        let b = rand_mat(&mut rng, k * n, 0.5);
        for &tile in &[TileSize::Whole, TileSize::Edge(4), TileSize::Edge(24), TileSize::Edge(64)]
        {
            let width_pairs =
                [(4u32, 4u32), (8, 8), (12, 12), (16, 16), (24, 24), (8, 16), (16, 8), (4, 24)];
            for &(ma, mb) in &width_pairs {
                let qa =
                    BfpTensor::from_f32(&a, m, k, ma, tile, &mut Rounding::NearestEven).unwrap();
                let qb =
                    BfpTensor::from_f32(&b, k, n, mb, tile, &mut Rounding::NearestEven).unwrap();
                let fast = ctx().matmul(&qa, &qb).unwrap();
                let slow = bfp_matmul_naive(&qa, &qb).unwrap();
                assert!(
                    fast == slow,
                    "packed kernel != naive at ma={ma} mb={mb} tile={tile:?} ({m}x{k}x{n})"
                );
            }
        }
    }
}

#[test]
fn wide_storage_of_narrow_mantissas_is_equivalent() {
    // The same logical 8-bit mantissas stored packed (i8) and wide (i32)
    // must multiply to bit-identical results: the kernels are generic
    // over storage, the numerics depend only on the values.
    let mut rng = SplitMix64::new(0xAB);
    let (m, k, n) = (24, 36, 20);
    let qa = rand_mantissas(&mut rng, m * k, 8);
    let qb = rand_mantissas(&mut rng, k * n, 8);
    let ea: Vec<i32> = (0..((m + 7) / 8) * ((k + 7) / 8)).map(|i| (i % 5) as i32 - 2).collect();
    let eb: Vec<i32> = (0..((k + 7) / 8) * ((n + 7) / 8)).map(|i| (i % 3) as i32).collect();
    let tile = TileSize::Edge(8);
    let a8 = BfpTensor::from_parts(m, k, 8, tile, pack(8, &qa), ea.clone()).unwrap();
    let b8 = BfpTensor::from_parts(k, n, 8, tile, pack(8, &qb), eb.clone()).unwrap();
    let a32 = BfpTensor::from_parts(m, k, 8, tile, Mantissas::I32(qa), ea).unwrap();
    let b32 = BfpTensor::from_parts(k, n, 8, tile, Mantissas::I32(qb), eb).unwrap();
    assert!(matches!(a8.mantissas, Mantissas::I8(_)));
    let packed = ctx().matmul(&a8, &b8).unwrap();
    let wide = ctx().matmul(&a32, &b32).unwrap();
    let naive = bfp_matmul_naive(&a8, &b8).unwrap();
    assert!(packed == wide && packed == naive, "storage class changed the numerics");
}

/// Worst-case tensors: every mantissa at the most negative value, so each
/// product attains the maximum magnitude `2^(ma+mb-2)` and tile partials
/// sit exactly at the proven bound `tile_k * 2^(ma+mb-2)`.
fn extreme_pair(
    m: usize,
    k: usize,
    n: usize,
    ma: u32,
    mb: u32,
    tile: TileSize,
) -> (BfpTensor, BfpTensor) {
    let qa = vec![-(1i32 << (ma - 1)); m * k];
    let qb = vec![-(1i32 << (mb - 1)); k * n];
    let (th, tw) = tile.edge_or(m, k);
    let ea = vec![0i32; m.div_ceil(th).max(1) * k.div_ceil(tw).max(1)];
    let (th2, tw2) = tile.edge_or(k, n);
    let eb = vec![0i32; k.div_ceil(th2).max(1) * n.div_ceil(tw2).max(1)];
    let a = BfpTensor::from_parts(m, k, ma, tile, pack(ma, &qa), ea).unwrap();
    let b = BfpTensor::from_parts(k, n, mb, tile, pack(mb, &qb), eb).unwrap();
    (a, b)
}

#[test]
fn overflow_boundary_worst_case_exact() {
    // Combos straddling the i32 accumulator boundary. For each, the
    // planned kernel (which picks i32 or i64 by the bound) must equal the
    // always-i64 naive kernel on all-extremal mantissas — if the bound
    // were wrong by even one product, the i32 path would wrap and diverge.
    for &(ma, mb, t, k) in &[
        (12u32, 12u32, 24usize, 48usize), // comfortably inside i32
        (13, 13, 127, 127),               // partial = 127 * 2^24, just under i32::MAX
        (13, 13, 128, 128),               // 2^31 — must fall back to i64
        (16, 16, 1, 7),                   // single-product tiles fit i32
        (16, 16, 2, 8),                   // two products overflow -> i64
        (24, 24, 24, 48),                 // widest supported, always i64
    ] {
        let (m, n) = (9usize, 11usize);
        let tile = TileSize::Edge(t);
        let (a, b) = extreme_pair(m, k, n, ma, mb, tile);
        let plan = ctx().with_tile(tile).plan_matmul(m, k, n, (ma, mb)).unwrap();
        assert_eq!(
            plan.uses_i32_acc(),
            acc_fits_i32(t.min(k), ma, mb),
            "plan must pre-resolve the accumulator class from the bound"
        );
        let fast = plan.execute(&a, &b).unwrap();
        let slow = bfp_matmul_naive(&a, &b).unwrap();
        assert!(
            fast == slow,
            "boundary case ma={ma} mb={mb} t={t} k={k} diverged (i32 fits: {})",
            acc_fits_i32(t.min(k), ma, mb)
        );
    }
}

#[test]
fn overflow_boundary_property_random_extremes() {
    // Random mantissas over the full range at boundary widths/tiles: the
    // packed kernel must match naive bit-for-bit everywhere.
    let mut rng = SplitMix64::new(0x0F10);
    for case in 0..40 {
        let ma = [12u32, 13, 14, 16, 20, 24][(rng.next_u64() % 6) as usize];
        let mb = [12u32, 13, 14, 16, 20, 24][(rng.next_u64() % 6) as usize];
        let t = [1usize, 2, 8, 24, 96][(rng.next_u64() % 5) as usize];
        let (m, k, n) = (
            1 + (rng.next_u64() % 12) as usize,
            1 + (rng.next_u64() % 100) as usize,
            1 + (rng.next_u64() % 12) as usize,
        );
        let tile = TileSize::Edge(t);
        let (th, tw) = tile.edge_or(m, k);
        let ea: Vec<i32> = (0..m.div_ceil(th).max(1) * k.div_ceil(tw).max(1))
            .map(|_| (rng.next_u64() % 7) as i32 - 3)
            .collect();
        let (th2, tw2) = tile.edge_or(k, n);
        let eb: Vec<i32> = (0..k.div_ceil(th2).max(1) * n.div_ceil(tw2).max(1))
            .map(|_| (rng.next_u64() % 7) as i32 - 3)
            .collect();
        let qa = rand_mantissas(&mut rng, m * k, ma);
        let qb = rand_mantissas(&mut rng, k * n, mb);
        let a = BfpTensor::from_parts(m, k, ma, tile, pack(ma, &qa), ea).unwrap();
        let b = BfpTensor::from_parts(k, n, mb, tile, pack(mb, &qb), eb).unwrap();
        let fast = ctx().matmul(&a, &b).unwrap();
        let slow = bfp_matmul_naive(&a, &b).unwrap();
        assert!(fast == slow, "case {case}: ma={ma} mb={mb} t={t} ({m}x{k}x{n})");
    }
}

#[test]
fn stochastic_quantization_thread_invariant() {
    // The per-tile substream design: 1-thread and N-thread stochastic
    // quantization produce identical tensors, hence identical products.
    let mut rng = SplitMix64::new(0x5EED);
    let (rows, cols) = (200, 160); // above the parallel floor
    let data = rand_mat(&mut rng, rows * cols, 1.5);
    let ctx1 = ctx().with_tile(TileSize::Edge(24)).with_threads(1);
    let ctx8 = ctx().with_tile(TileSize::Edge(24)).with_threads(8);
    for m in [8u32, 12] {
        let mut r1 = Xorshift32::new(0xC0FE);
        let mut r2 = Xorshift32::new(0xC0FE);
        let t1 = ctx1.quantize(&data, rows, cols, m, &mut Rounding::Stochastic(&mut r1)).unwrap();
        let t8 = ctx8.quantize(&data, rows, cols, m, &mut Rounding::Stochastic(&mut r2)).unwrap();
        assert!(t1.mantissas == t8.mantissas && t1.exponents == t8.exponents, "m={m}");
        // and the caller RNGs advanced identically (exactly one draw)
        assert_eq!(r1.next_u32(), r2.next_u32());
    }
}

#[test]
fn matmul_and_fused_thread_invariant() {
    let mut rng = SplitMix64::new(0x7AB);
    let (m, k, n) = (128, 96, 80); // above the parallel floor
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qb =
        BfpTensor::from_f32(&b, k, n, 8, TileSize::Edge(24), &mut Rounding::NearestEven).unwrap();
    let qa =
        BfpTensor::from_f32(&a, m, k, 8, TileSize::Edge(24), &mut Rounding::NearestEven).unwrap();
    let mm1 = ctx().with_threads(1).matmul(&qa, &qb).unwrap();
    let mm8 = ctx().with_threads(8).matmul(&qa, &qb).unwrap();
    assert!(mm1 == mm8, "blocked matmul must be thread-count invariant");

    let mut r1 = Xorshift32::new(3);
    let mut r8 = Xorshift32::new(3);
    let f1 = ctx()
        .with_threads(1)
        .quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r1), &qb)
        .unwrap();
    let f8 = ctx()
        .with_threads(8)
        .quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r8), &qb)
        .unwrap();
    assert!(f1 == f8, "fused path must be thread-count invariant");
}

#[test]
fn fused_equals_materialized_at_parallel_sizes() {
    // The unit tests cover small shapes; here the parallel code paths
    // (band scratch quantization) are actually engaged.
    let mut rng = SplitMix64::new(0xFA5);
    let (m, k, n) = (96, 120, 64);
    let a = rand_mat(&mut rng, m * k, 2.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    for &tile in &[TileSize::Edge(24), TileSize::Edge(32), TileSize::Whole] {
        let qb = BfpTensor::from_f32(&b, k, n, 8, tile, &mut Rounding::NearestEven).unwrap();
        let mut ra = Xorshift32::new(0x11);
        let mut rb = Xorshift32::new(0x11);
        let qa =
            BfpTensor::from_f32(&a, m, k, 8, tile, &mut Rounding::Stochastic(&mut ra)).unwrap();
        let want = ctx().matmul(&qa, &qb).unwrap();
        let got = ctx().quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut rb), &qb).unwrap();
        assert!(got == want, "fused != materialized at tile {tile:?}");
    }
}

#[test]
fn packed_storage_is_actually_smaller() {
    let data: Vec<f32> = (0..256 * 256).map(|i| ((i % 97) as f32 - 48.0) / 9.0).collect();
    let t8 =
        BfpTensor::from_f32(&data, 256, 256, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
    let t12 =
        BfpTensor::from_f32(&data, 256, 256, 12, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
    let t20 =
        BfpTensor::from_f32(&data, 256, 256, 20, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
    // i8 storage: 1 byte/elem; i16: 2; i32: 4 (plus identical exponent cost)
    assert_eq!(t12.heap_bytes() - t8.heap_bytes(), 256 * 256);
    assert_eq!(t20.heap_bytes() - t12.heap_bytes(), 2 * 256 * 256);
}
