//! Whole-matmul contract tests for the SIMD kernel family: every
//! detected ISA — forced via `BfpContext::with_isa`, which re-packs the
//! B panels at that family's register width — must be bit-identical to
//! the always-i64 naive reference and to the forced-scalar path, across
//! storage classes, mixed operand widths, both accumulator widths, and
//! ragged shapes that exercise vector-panel padding. Stochastic
//! rounding must consume its per-tile RNG substreams in exact element
//! order whatever family is active. (Kernel-level differentials live in
//! `bfp::kernels::tests`; CI additionally runs the whole suite under
//! `HBFP_SIMD=off` and `HBFP_SIMD=auto`.)

use hbfp::bfp::{
    bfp_matmul_naive, kernels, quantize_value, BfpContext, BfpTensor, Isa, Rounding, TileSize,
};
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn ctx() -> BfpContext {
    BfpContext::from_env()
}

fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() * scale).collect()
}

fn quantize(data: &[f32], rows: usize, cols: usize, bits: u32, tile: TileSize) -> BfpTensor {
    BfpTensor::from_f32(data, rows, cols, bits, tile, &mut Rounding::NearestEven).unwrap()
}

#[test]
fn every_detected_isa_matches_naive_bitwise() {
    let mut rng = SplitMix64::new(0x51AD);
    // ragged shapes: nothing divides the 16/32-wide vector panels, edge
    // tiles in every dimension, single rows/cols, k spanning tiles
    for &(m, k, n) in &[
        (17usize, 23usize, 19usize),
        (48, 48, 48),
        (5, 64, 30),
        (1, 1, 1),
        (3, 129, 33),
        (40, 100, 3),
    ] {
        let a = rand_mat(&mut rng, m * k, 2.0);
        let b = rand_mat(&mut rng, k * n, 0.5);
        for &tile in &[TileSize::Whole, TileSize::Edge(4), TileSize::Edge(24)] {
            // (8,8): i8 kernels; (12,12): i16 with i32 acc; (16,16) at
            // t=24: i16 with i64 acc; mixed pairs: scalar fallback
            for &(ma, mb) in &[(8u32, 8u32), (12, 12), (16, 16), (8, 16), (20, 20), (4, 24)] {
                let qa = quantize(&a, m, k, ma, tile);
                let qb = quantize(&b, k, n, mb, tile);
                let naive = bfp_matmul_naive(&qa, &qb).unwrap();
                for &isa in &kernels::detected() {
                    let got = ctx().with_threads(4).with_isa(isa).matmul(&qa, &qb).unwrap();
                    assert!(
                        got == naive,
                        "isa={isa:?} diverged at ma={ma} mb={mb} tile={tile:?} ({m}x{k}x{n})"
                    );
                }
            }
        }
    }
}

#[test]
fn unsupported_isa_requests_clamp_safely() {
    // Every Isa variant — including ones this CPU cannot run — must
    // execute via clamping and still produce the reference bits.
    let mut rng = SplitMix64::new(0xC1A);
    let (m, k, n) = (12, 40, 28);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qa = quantize(&a, m, k, 8, TileSize::Edge(16));
    let qb = quantize(&b, k, n, 8, TileSize::Edge(16));
    let naive = bfp_matmul_naive(&qa, &qb).unwrap();
    for isa in [Isa::Scalar, Isa::Sse41, Isa::Avx2, Isa::Neon] {
        let got = ctx().with_threads(2).with_isa(isa).matmul(&qa, &qb).unwrap();
        assert!(got == naive, "clamped {isa:?} diverged");
    }
}

#[test]
fn forced_widths_repack_the_shared_cache_coherently() {
    // Alternating panel widths on one tensor (scalar rung then the
    // active family, as the bench ladder does) must repack the cache,
    // never serve a stale width, and agree bit-for-bit throughout.
    let mut rng = SplitMix64::new(0xCAFE);
    let (m, k, n) = (32, 48, 40);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qa = quantize(&a, m, k, 8, TileSize::Edge(24));
    let qb = quantize(&b, k, n, 8, TileSize::Edge(24));
    let naive = bfp_matmul_naive(&qa, &qb).unwrap();
    let scalar_ctx = ctx().with_threads(4).with_isa(Isa::Scalar);
    for round in 0..3 {
        let scalar = scalar_ctx.matmul(&qa, &qb).unwrap();
        assert_eq!(qb.packed_panels_nr(Isa::Scalar.panel_nr()).nr, Isa::Scalar.panel_nr());
        let active = ctx().matmul(&qa, &qb).unwrap();
        assert_eq!(qb.packed_panels().nr, kernels::active_panel_nr());
        assert!(scalar == naive && active == naive, "round {round} diverged");
    }
}

#[test]
fn stochastic_draw_sequence_is_isa_independent() {
    // The stochastic converter path is scalar by design: one RNG draw
    // per element, in element order within each tile. Replay the
    // per-tile substreams by hand and require the tensor to match draw
    // for draw — if any SIMD path consumed or reordered draws, this
    // (and the HBFP_SIMD=off CI leg) would diverge.
    let (rows, cols, bits, te) = (40usize, 36usize, 8u32, 16usize);
    let mut rng = SplitMix64::new(0xD12A);
    let data = rand_mat(&mut rng, rows * cols, 1.5);
    let seed = 0x5EED_u32;

    let mut caller_rng = Xorshift32::new(seed);
    let t = BfpTensor::from_f32(
        &data,
        rows,
        cols,
        bits,
        TileSize::Edge(te),
        &mut Rounding::Stochastic(&mut caller_rng),
    )
    .unwrap();

    // capture consumes exactly one u32 from the caller's RNG
    let mut replay_rng = Xorshift32::new(seed);
    let base = replay_rng.next_u32();
    assert_eq!(caller_rng.next_u32(), replay_rng.next_u32(), "capture must draw exactly once");

    let tiles_c = cols.div_ceil(te);
    for tr in 0..rows.div_ceil(te) {
        let (r0, r1) = (tr * te, ((tr + 1) * te).min(rows));
        for tc in 0..tiles_c {
            let (c0, c1) = (tc * te, ((tc + 1) * te).min(cols));
            let mut sub = Xorshift32::substream(base, (tr * tiles_c + tc) as u64);
            for r in r0..r1 {
                for c in c0..c1 {
                    let e = t.exponent_at(r, c);
                    let want = quantize_value(
                        data[r * cols + c],
                        e,
                        bits,
                        &mut Rounding::Stochastic(&mut sub),
                    );
                    assert_eq!(
                        t.mantissa_at(r, c),
                        want,
                        "draw order broke at ({r},{c}) tile ({tr},{tc})"
                    );
                }
            }
        }
    }
}

#[test]
fn active_family_is_detected_and_selection_is_sane() {
    // the process-wide family must be executable on this CPU, and the
    // default context must resolve to it
    assert!(kernels::detected().contains(&kernels::active()));
    assert_eq!(ctx().isa(), kernels::active());
    // HBFP_SIMD semantics (pure selection logic; the env var itself is
    // exercised by the CI matrix legs)
    use hbfp::bfp::kernels::{select, CpuCaps, SimdPref};
    let here = CpuCaps::detect();
    assert_eq!(select(Some(SimdPref::Off), here), Isa::Scalar);
    let auto = select(Some(SimdPref::Auto), here);
    assert_eq!(auto, select(None, here));
    assert!(kernels::detected().contains(&auto));
}
