//! Contract tests for the persistent worker pool and the packed-panel
//! GEMM path, driven through the context API: pooled dispatch must be
//! bit-identical to per-call scoped spawns, the panel microkernel must
//! be bit-identical to the row-major walk (and the naive reference)
//! across all three storage classes, panel caches must never leak across
//! a `narrow_view` repack, and concurrent matmuls from multiple caller
//! threads must stay deterministic.

use std::sync::Arc;

use hbfp::bfp::{
    bfp_matmul_naive, kernels, BfpContext, BfpTensor, MatmulKernel, Mantissas, Rounding, TileSize,
};
use hbfp::util::pool::ParBackend;
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn ctx() -> BfpContext {
    BfpContext::from_env()
}

fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() * scale).collect()
}

fn quantize(data: &[f32], rows: usize, cols: usize, bits: u32, tile: TileSize) -> BfpTensor {
    BfpTensor::from_f32(data, rows, cols, bits, tile, &mut Rounding::NearestEven).unwrap()
}

#[test]
fn pooled_equals_scoped_bitwise() {
    // Same panel kernel under both dispatch backends, sized above the
    // parallel floor so both actually fan out.
    let mut rng = SplitMix64::new(0x9001);
    let (m, k, n) = (96, 112, 88);
    let a = rand_mat(&mut rng, m * k, 1.5);
    let b = rand_mat(&mut rng, k * n, 0.8);
    let pooled_ctx = ctx().with_threads(4).with_backend(ParBackend::Pooled);
    let scoped_ctx = ctx().with_threads(4).with_backend(ParBackend::Scoped);
    for &(ma, mb) in &[(8u32, 8u32), (12, 12), (8, 16), (20, 20)] {
        let qa = quantize(&a, m, k, ma, TileSize::Edge(24));
        let qb = quantize(&b, k, n, mb, TileSize::Edge(24));
        let pooled = pooled_ctx.matmul(&qa, &qb).unwrap();
        let scoped = scoped_ctx.matmul(&qa, &qb).unwrap();
        let naive = bfp_matmul_naive(&qa, &qb).unwrap();
        assert!(pooled == scoped, "backends diverged at ma={ma} mb={mb}");
        assert!(pooled == naive, "panel kernel != naive at ma={ma} mb={mb}");
    }
}

#[test]
fn packed_panel_equals_rowmajor_across_width_classes() {
    // i8 (m<=8), i16 (m<=16), i32 (m>16) storage classes, mixed pairs,
    // ragged shapes that exercise panel padding, and TileSize::Whole.
    let mut rng = SplitMix64::new(0xABCD);
    let rowmajor_ctx = ctx().with_kernel(MatmulKernel::RowMajor).with_threads(4);
    for &(m, k, n) in &[(17usize, 23usize, 19usize), (48, 48, 48), (5, 64, 30), (40, 100, 3)] {
        let a = rand_mat(&mut rng, m * k, 2.0);
        let b = rand_mat(&mut rng, k * n, 0.5);
        for &tile in &[TileSize::Whole, TileSize::Edge(4), TileSize::Edge(24)] {
            for &(ma, mb) in &[(8u32, 8u32), (12, 12), (20, 20), (8, 20), (20, 8), (4, 12)] {
                let qa = quantize(&a, m, k, ma, tile);
                let qb = quantize(&b, k, n, mb, tile);
                let panel = ctx().matmul(&qa, &qb).unwrap();
                let rowmajor = rowmajor_ctx.matmul(&qa, &qb).unwrap();
                let naive = bfp_matmul_naive(&qa, &qb).unwrap();
                assert!(
                    panel == rowmajor && panel == naive,
                    "panel kernel diverged at ma={ma} mb={mb} tile={tile:?} ({m}x{k}x{n})"
                );
            }
        }
    }
}

#[test]
fn fused_uses_panels_and_matches_materialized() {
    let mut rng = SplitMix64::new(0xFEED);
    let (m, k, n) = (64, 96, 72);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qb = quantize(&b, k, n, 8, TileSize::Edge(24));
    let mut r1 = Xorshift32::new(0x51);
    let mut r2 = Xorshift32::new(0x51);
    let qa =
        BfpTensor::from_f32(&a, m, k, 8, TileSize::Edge(24), &mut Rounding::Stochastic(&mut r1))
            .unwrap();
    let want = ctx().matmul(&qa, &qb).unwrap();
    let got = ctx().quantize_matmul(&a, m, 8, &mut Rounding::Stochastic(&mut r2), &qb).unwrap();
    assert!(got == want, "fused packed-panel path != materialized");
    assert!(qb.has_packed_panels(), "fused path must build the panel cache");
}

#[test]
fn panel_cache_invalidated_by_narrow_view_repack() {
    let mut rng = SplitMix64::new(0x1DEA);
    let (m, k, n) = (24, 40, 32);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let wide = quantize(&b, k, n, 16, TileSize::Edge(8));

    // populate the wide tensor's cache (i16 panels)
    let qa16 = quantize(&a, m, k, 16, TileSize::Edge(8));
    let _ = ctx().matmul(&qa16, &wide).unwrap();
    assert!(wide.has_packed_panels());
    let wide_pp = wide.packed_panels();
    assert_eq!(wide_pp.data.elem_bits(), 16);

    // the narrow repack starts with an empty cache and builds i8 panels
    let narrow = wide.narrow_view(8, &mut Rounding::NearestEven).unwrap();
    assert!(!narrow.has_packed_panels(), "narrow_view must not inherit panels");
    let qa8 = quantize(&a, m, k, 8, TileSize::Edge(8));
    let fast = ctx().matmul(&qa8, &narrow).unwrap();
    let slow = bfp_matmul_naive(&qa8, &narrow).unwrap();
    assert!(fast == slow, "narrow tensor's rebuilt panels diverged from naive");
    let narrow_pp = narrow.packed_panels();
    assert_eq!(narrow_pp.data.elem_bits(), 8, "panels must repack at the narrow class");
    assert!(matches!(narrow.mantissas, Mantissas::I8(_)));

    // clearing forces a repack that still agrees
    narrow.clear_panel_cache();
    assert!(!narrow.has_packed_panels());
    let again = ctx().matmul(&qa8, &narrow).unwrap();
    assert!(again == slow);
}

#[test]
fn clone_shares_valid_panels() {
    let mut rng = SplitMix64::new(0xC0);
    let b = rand_mat(&mut rng, 32 * 32, 1.0);
    let qb = quantize(&b, 32, 32, 8, TileSize::Edge(8));
    let pp = qb.packed_panels();
    let cloned = qb.clone();
    assert!(cloned.has_packed_panels(), "clone may reuse the panels of identical mantissas");
    assert!(*cloned.packed_panels() == *pp);
}

#[test]
fn concurrent_matmuls_from_two_callers_are_deterministic() {
    // Two caller threads hammer the shared global pool with interleaved
    // plan executions; every result must equal the single-threaded
    // reference.
    let mut rng = SplitMix64::new(0x70FF);
    let (m, k, n) = (96, 80, 72); // above the parallel floor
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qa = Arc::new(quantize(&a, m, k, 8, TileSize::Edge(16)));
    let qb = Arc::new(quantize(&b, k, n, 8, TileSize::Edge(16)));
    qb.packed_panels();
    let reference = ctx().with_threads(1).matmul(&qa, &qb).unwrap();
    let plan = ctx()
        .with_threads(4)
        .with_tile(TileSize::Edge(16))
        .plan_matmul(m, k, n, (8, 8))
        .unwrap();

    std::thread::scope(|scope| {
        for _caller in 0..2 {
            let qa = Arc::clone(&qa);
            let qb = Arc::clone(&qb);
            let reference = &reference;
            let plan = &plan;
            scope.spawn(move || {
                for round in 0..8 {
                    let got = plan.execute(&qa, &qb).unwrap();
                    assert!(got == *reference, "round {round} diverged under contention");
                }
            });
        }
    });
}

#[test]
fn small_problems_take_the_inline_path_with_identical_results() {
    // Below the MAC floor the plan resolves to a single lane and runs
    // inline on the caller — same kernel body, same bits as the naive
    // reference.
    let mut rng = SplitMix64::new(0x5A11);
    let (m, k, n) = (12, 16, 10);
    let a = rand_mat(&mut rng, m * k, 1.0);
    let b = rand_mat(&mut rng, k * n, 1.0);
    let qa = quantize(&a, m, k, 8, TileSize::Edge(8));
    let qb = quantize(&b, k, n, 8, TileSize::Edge(8));
    let plan = ctx().with_tile(TileSize::Edge(8)).plan_matmul(m, k, n, (8, 8)).unwrap();
    assert_eq!(plan.threads(), 1, "below the floor the plan must resolve to inline");
    let fast = plan.execute(&qa, &qb).unwrap();
    let slow = bfp_matmul_naive(&qa, &qb).unwrap();
    assert!(fast == slow);
}

#[test]
fn panel_geometry_matches_active_family() {
    let mut rng = SplitMix64::new(0x42);
    let b = rand_mat(&mut rng, 48 * 30, 1.0);
    let qb = quantize(&b, 48, 30, 8, TileSize::Edge(24));
    let pp = qb.packed_panels();
    // the default cache packs at the active SIMD family's register width
    let nr = kernels::active_panel_nr();
    assert_eq!(pp.nr, nr);
    assert_eq!(nr, kernels::active().panel_nr());
    assert_eq!(pp.t, 24);
    assert_eq!(pp.tiles_k, 2);
    assert_eq!(pp.tiles_j, 2);
    assert_eq!(pp.panels_per_tile, 24usize.div_ceil(nr));
    // and a default-context plan pre-resolves the same width
    let plan = ctx().plan_matmul(48, 48, 30, (8, 8)).unwrap();
    assert_eq!(plan.panel_nr(), nr);
}
