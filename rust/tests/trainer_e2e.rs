//! End-to-end trainer test: full Trainer::run over real artifacts with the
//! synthetic data pipeline — short runs, but exercising init, prefetching,
//! stepping, LR schedule, evaluation, history, checkpointing, and the
//! hbfp-vs-fp32 comparison the whole repo exists to make.

use std::path::Path;
use std::sync::Arc;

use hbfp::coordinator::{Checkpoint, LrSchedule, RunConfig, Trainer};
use hbfp::runtime::{Manifest, Role};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Ok(m) => Some(Arc::new(m)),
        Err(e) => {
            eprintln!("SKIP trainer_e2e: {e:#} — run `make artifacts`");
            None
        }
    }
}

#[test]
fn mlp_fp32_short_run_learns() {
    let Some(m) = manifest() else { return };
    let trainer = Trainer::new(m).unwrap();
    let cfg = RunConfig::new("mlp-cifar10like-fp32", 40)
        .with_lr(LrSchedule::Constant { lr: 0.1 })
        .with_eval_every(20);
    let r = trainer.run(&cfg).unwrap();
    assert!(!r.diverged);
    assert!(r.history.evals.len() >= 2, "periodic + final evals");
    let first = r.history.steps.first().unwrap().loss;
    let last = r.history.tail_loss(5).unwrap();
    assert!(last < first, "loss should decrease: {first} -> {last}");
    // 10-class task: must beat chance by a margin after 40 steps
    assert!(r.final_error < 0.85, "final error {}", r.final_error);
}

#[test]
fn hbfp_tracks_fp32_on_mlp() {
    let Some(m) = manifest() else { return };
    let trainer = Trainer::new(m).unwrap();
    let run = |combo: &str| {
        let cfg = RunConfig::new(combo, 60).with_lr(LrSchedule::Constant { lr: 0.1 });
        trainer.run(&cfg).unwrap()
    };
    let fp32 = run("mlp-cifar10like-fp32");
    let hbfp = run("mlp-cifar10like-hbfpp8_16_t24");
    assert!(!fp32.diverged && !hbfp.diverged);
    // the paper's claim, scaled down: hbfp8_16 stays close to fp32
    let gap = (hbfp.final_error - fp32.final_error).abs();
    assert!(gap < 0.15, "hbfp-vs-fp32 gap {gap} too large (fp32 {}, hbfp {})",
        fp32.final_error, hbfp.final_error);
}

#[test]
fn deterministic_given_seed() {
    let Some(m) = manifest() else { return };
    let trainer = Trainer::new(m).unwrap();
    let mk = || {
        RunConfig::new("mlp-cifar10like-fp32", 10)
            .with_seed(3)
            .with_lr(LrSchedule::Constant { lr: 0.1 })
    };
    let a = trainer.run(&mk()).unwrap();
    let b = trainer.run(&mk()).unwrap();
    assert_eq!(a.final_loss, b.final_loss, "same seed => same run");
    let steps_a: Vec<f32> = a.history.steps.iter().map(|s| s.loss).collect();
    let steps_b: Vec<f32> = b.history.steps.iter().map(|s| s.loss).collect();
    assert_eq!(steps_a, steps_b);
}

#[test]
fn checkpoint_written_and_reloadable() {
    let Some(m) = manifest() else { return };
    let dir = std::env::temp_dir().join("hbfp_e2e_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let trainer = Trainer::new(m.clone()).unwrap();
    let mut cfg = RunConfig::new("mlp-cifar10like-fp32", 5)
        .with_lr(LrSchedule::Constant { lr: 0.1 });
    cfg.checkpoint_dir = Some(dir.clone());
    trainer.run(&cfg).unwrap();
    let path = dir.join("mlp-cifar10like-fp32.ckpt");
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, 5);
    let art = m.artifact("mlp-cifar10like-fp32", Role::Train).unwrap();
    ck.check_against("mlp-cifar10like-fp32", &art.inputs[..art.state_len]).unwrap();
}

#[test]
fn lr_schedule_is_applied() {
    let Some(m) = manifest() else { return };
    let trainer = Trainer::new(m).unwrap();
    let cfg = RunConfig::new("mlp-cifar10like-fp32", 20)
        .with_lr(LrSchedule::StepDecay { base: 0.1, gamma: 0.1, milestones: vec![10] });
    let mut c = cfg.clone();
    c.log_every = 1;
    let r = trainer.run(&c).unwrap();
    let lr_at = |step: usize| r.history.steps.iter().find(|s| s.step == step).unwrap().lr;
    assert_eq!(lr_at(5), 0.1);
    assert!((lr_at(15) - 0.01).abs() < 1e-6);
}
