//! Native `nn` training-path integration:
//!
//! (a) finite-difference gradient checks for every layer at FP32,
//! (b) GEMM-level bit-identity of the HBFP path against
//!     `bfp_matmul_naive` (the spec kernel),
//! (c) a 200-step MLP smoke: loss decreases, curves are bitwise
//!     identical at 1 vs 4 threads, plan cache warms, datasets are
//!     reused across the FP32-vs-HBFP combo pair,
//! (d) the watchdog: an injected `nan-activation` fault mid-run is
//!     detected at the GEMM guard (not the loss — ReLU and softmax can
//!     both absorb a NaN), rolled back, widened away, and the run
//!     finishes clean and deterministic.
//!
//! Injector discipline: every test that steps a model installs an
//! explicit injector, which serializes them on the install lock and
//! shields them from `HBFP_FAULT` (the CI fault matrix only drives the
//! `fault_tolerance` binary).

use hbfp::bfp::{bfp_matmul_naive, BfpContext, Rounding, TileSize};
use hbfp::coordinator::metrics::{RecoveryAction, RecoveryKind};
use hbfp::coordinator::{run_resilient, FaultTolerantModel, LrSchedule, RunConfig};
use hbfp::nn::{
    Embedding, Layer, Linear, NnContext, Precision, Relu, Rnn, SoftmaxCrossEntropy, Tanh, Trainer,
};
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};
use hbfp::util::rng::Xorshift32;

fn fp32_nc() -> NnContext {
    NnContext::new(BfpContext::from_env().with_threads(1), Precision::Fp32)
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `|fd - analytic|` within a relative-ish tolerance: FD with eps=1e-2
/// on O(1) values carries ~1e-5 rounding noise and ~eps^2 truncation.
fn assert_close(fd: f32, g: f32, what: &str) {
    assert!(
        (fd - g).abs() <= 1e-2 * (1.0 + g.abs()),
        "{what}: finite-difference {fd} vs analytic {g}"
    );
}

const EPS: f32 = 1e-2;

// ---------------------------------------------------------------- (a) --

#[test]
fn fd_gradients_linear() {
    let mut rng = Xorshift32::new(31);
    let mut layer = Linear::new("fc", 3, 2, &mut rng);
    let mut nc = fp32_nc();
    let rows = 2;
    let x = vec![0.5, -0.3, 0.8, 1.2, 0.1, -0.7];
    let r = vec![0.7, -0.4, 0.2, 0.9];

    layer.forward(&mut nc, &x, rows).unwrap();
    let dx = layer.backward(&mut nc, &r, rows).unwrap();
    let grad_w = layer.w.g.clone();
    let grad_b = layer.b.g.clone();

    for i in 0..grad_w.len() {
        let orig = layer.w.w[i];
        layer.w.w[i] = orig + EPS;
        let yp = layer.forward(&mut nc, &x, rows).unwrap();
        layer.w.w[i] = orig - EPS;
        let ym = layer.forward(&mut nc, &x, rows).unwrap();
        layer.w.w[i] = orig;
        assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), grad_w[i], "linear w");
    }
    for i in 0..grad_b.len() {
        let orig = layer.b.w[i];
        layer.b.w[i] = orig + EPS;
        let yp = layer.forward(&mut nc, &x, rows).unwrap();
        layer.b.w[i] = orig - EPS;
        let ym = layer.forward(&mut nc, &x, rows).unwrap();
        layer.b.w[i] = orig;
        assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), grad_b[i], "linear b");
    }
    for i in 0..x.len() {
        let mut xp = x.clone();
        xp[i] += EPS;
        let yp = layer.forward(&mut nc, &xp, rows).unwrap();
        let mut xm = x.clone();
        xm[i] -= EPS;
        let ym = layer.forward(&mut nc, &xm, rows).unwrap();
        assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), dx[i], "linear dx");
    }
}

#[test]
fn fd_gradients_activations() {
    // Inputs chosen away from the ReLU kink (FD is invalid at 0).
    let x = vec![0.5, -0.3, 1.2, -0.7];
    let r = vec![0.3, 0.9, -0.5, 0.4];
    let mut nc = fp32_nc();
    for (name, layer) in
        [("relu", Box::new(Relu::new()) as Box<dyn Layer>), ("tanh", Box::new(Tanh::new()))]
    {
        let mut layer = layer;
        layer.forward(&mut nc, &x, 2).unwrap();
        let dx = layer.backward(&mut nc, &r, 2).unwrap();
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += EPS;
            let yp = layer.forward(&mut nc, &xp, 2).unwrap();
            let mut xm = x.clone();
            xm[i] -= EPS;
            let ym = layer.forward(&mut nc, &xm, 2).unwrap();
            assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), dx[i], name);
        }
    }
}

#[test]
fn fd_gradients_rnn() {
    let mut rng = Xorshift32::new(32);
    let mut rnn = Rnn::new("rnn", 2, 3, &mut rng);
    let mut nc = fp32_nc();
    let (batch, t_len) = (2, 2);
    let x = vec![0.4, -0.2, 0.7, 0.1, -0.5, 0.3, 0.2, -0.8];
    let r: Vec<f32> =
        (0..t_len * batch * 3).map(|i| 0.3 + 0.1 * (i as f32) * if i % 2 == 0 { 1.0 } else { -1.0 }).collect();

    rnn.forward(&mut nc, &x, batch, t_len).unwrap();
    let dx = rnn.backward(&mut nc, &r).unwrap();
    let (gwx, gwh, gb) = (rnn.wx.g.clone(), rnn.wh.g.clone(), rnn.b.g.clone());

    let fd_param = |rnn: &mut Rnn, nc: &mut NnContext, which: usize, i: usize| -> f32 {
        let bump = |rnn: &mut Rnn, d: f32| match which {
            0 => rnn.wx.w[i] += d,
            1 => rnn.wh.w[i] += d,
            _ => rnn.b.w[i] += d,
        };
        bump(rnn, EPS);
        let yp = rnn.forward(nc, &x, batch, t_len).unwrap();
        bump(rnn, -2.0 * EPS);
        let ym = rnn.forward(nc, &x, batch, t_len).unwrap();
        bump(rnn, EPS);
        (dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS)
    };
    for i in 0..gwx.len() {
        let fd = fd_param(&mut rnn, &mut nc, 0, i);
        assert_close(fd, gwx[i], "rnn wx");
    }
    for i in 0..gwh.len() {
        let fd = fd_param(&mut rnn, &mut nc, 1, i);
        assert_close(fd, gwh[i], "rnn wh");
    }
    for i in 0..gb.len() {
        let fd = fd_param(&mut rnn, &mut nc, 2, i);
        assert_close(fd, gb[i], "rnn b");
    }
    for i in 0..x.len() {
        let mut xp = x.clone();
        xp[i] += EPS;
        let yp = rnn.forward(&mut nc, &xp, batch, t_len).unwrap();
        let mut xm = x.clone();
        xm[i] -= EPS;
        let ym = rnn.forward(&mut nc, &xm, batch, t_len).unwrap();
        assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), dx[i], "rnn dx");
    }
}

#[test]
fn fd_gradients_embedding() {
    let mut rng = Xorshift32::new(33);
    let mut emb = Embedding::new("emb", 5, 3, &mut rng);
    let tokens = [1i32, 4, 1];
    let r = vec![0.5, -0.2, 0.8, 0.3, 0.7, -0.6, 0.1, 0.4, 0.9];

    emb.forward(&tokens).unwrap();
    emb.backward(&r).unwrap();
    let grad = emb.table.g.clone();

    for i in 0..grad.len() {
        let orig = emb.table.w[i];
        emb.table.w[i] = orig + EPS;
        let yp = emb.forward(&tokens).unwrap();
        emb.table.w[i] = orig - EPS;
        let ym = emb.forward(&tokens).unwrap();
        emb.table.w[i] = orig;
        assert_close((dot(&yp, &r) - dot(&ym, &r)) / (2.0 * EPS), grad[i], "embedding table");
    }
}

#[test]
fn fd_gradients_softmax_xent() {
    let mut loss = SoftmaxCrossEntropy::new();
    let logits = vec![1.0, -0.5, 0.3, 0.2, 0.8, -1.1];
    let targets = [2i32, 0];
    let (_, _) = loss.forward(&logits, &targets, 2, 3).unwrap();
    let grad = loss.backward();
    for i in 0..logits.len() {
        let mut lp = logits.clone();
        lp[i] += EPS;
        let (fp, _) = loss.forward(&lp, &targets, 2, 3).unwrap();
        let mut lm = logits.clone();
        lm[i] -= EPS;
        let (fm, _) = loss.forward(&lm, &targets, 2, 3).unwrap();
        assert_close((fp - fm) / (2.0 * EPS), grad[i], "softmax-xent");
    }
}

// ---------------------------------------------------------------- (b) --

#[test]
fn hbfp_gemm_bit_identical_to_naive_reference() {
    let mut a = Vec::new();
    let mut b = Vec::new();
    let mut rng = Xorshift32::new(44);
    let (m, k, n) = (5, 9, 4);
    for _ in 0..m * k {
        a.push(rng.next_f32() * 2.0 - 1.0);
    }
    for _ in 0..k * n {
        b.push(rng.next_f32() * 2.0 - 1.0);
    }
    for threads in [1usize, 4] {
        let ctx = BfpContext::from_env().with_threads(threads).with_tile(TileSize::Edge(8));
        let qa = ctx.quantize(&a, m, k, 8, &mut Rounding::NearestEven).unwrap();
        let qb = ctx.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();
        let reference = bfp_matmul_naive(&qa, &qb).unwrap();

        let mut nc = NnContext::new(ctx, Precision::Hbfp { bits: 8 });
        let got = nc.gemm(&a, &b, m, k, n).unwrap();
        assert_eq!(got.len(), reference.len());
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(
                g.to_bits(),
                r.to_bits(),
                "nn gemm[{i}] {g} != naive {r} at {threads} threads"
            );
        }
        // second call at the same shape must be a plan-cache hit
        nc.gemm(&a, &b, m, k, n).unwrap();
        assert_eq!((nc.plans.misses(), nc.plans.hits()), (1, 1));
    }
}

// ---------------------------------------------------------------- (c) --

#[test]
fn mlp_smoke_learns_reuses_datasets_and_is_thread_invariant() {
    let _guard = fault::install(FaultInjector::none());
    let steps = 200;
    let t1 = Trainer::with_context(BfpContext::from_env().with_threads(1));
    let t4 = Trainer::with_context(BfpContext::from_env().with_threads(4));
    for (i, combo) in ["mlp-tinyimg-fp32", "mlp-tinyimg-hbfp8_t8"].iter().enumerate() {
        let cfg = RunConfig::new(combo, steps)
            .with_seed(5)
            .with_lr(LrSchedule::Constant { lr: 0.02 });
        let r1 = t1.run(&cfg).unwrap();
        assert_eq!(r1.history.steps.len(), steps, "{combo}");
        assert!(!r1.history.diverged(), "{combo}");
        let head: f32 =
            r1.history.steps[..20].iter().map(|s| s.loss).sum::<f32>() / 20.0;
        let tail = r1.history.tail_loss(20).unwrap();
        assert!(
            tail < head,
            "{combo}: loss must decrease ({head} -> {tail} over {steps} steps)"
        );
        assert!(!r1.history.evals.is_empty(), "{combo}: final eval always runs");
        if combo.contains("hbfp") {
            assert!(r1.plan_misses > 0 && r1.plan_hits > 0, "{combo}: plan cache must warm");
        } else {
            assert_eq!(r1.plan_hits + r1.plan_misses, 0, "{combo}: fp32 never plans");
        }
        if i > 0 {
            assert!(
                r1.dataset_cache_hit,
                "second combo over the same (dataset, seed) must reuse the generated dataset"
            );
            assert!(t1.dataset_cache().hits() >= 1);
        }

        let r4 = t4.run(&cfg).unwrap();
        let c1: Vec<u32> = r1.history.steps.iter().map(|s| s.loss.to_bits()).collect();
        let c4: Vec<u32> = r4.history.steps.iter().map(|s| s.loss.to_bits()).collect();
        assert_eq!(c1, c4, "{combo}: loss curve must be bitwise identical at 1 vs 4 threads");
        let e1: Vec<(usize, u32)> =
            r1.history.evals.iter().map(|e| (e.step, e.loss.to_bits())).collect();
        let e4: Vec<(usize, u32)> =
            r4.history.evals.iter().map(|e| (e.step, e.loss.to_bits())).collect();
        assert_eq!(e1, e4, "{combo}: eval records must match bitwise too");
    }
}

// ---------------------------------------------------------------- (d) --

#[test]
fn watchdog_recovers_injected_nan_via_guard_and_stays_deterministic() {
    // rate 1.0 while the width class is <= 8 bits: the first step always
    // poisons an activation. ReLU would silently map that NaN to 0 and
    // the loss would come out finite — the hazard must instead surface
    // through the GEMM input scan as a StepError.
    let _guard = fault::install(FaultInjector::from_specs(&[FaultSpec {
        site: FaultSite::NanActivation,
        rate: 1.0,
        seed: 1,
    }]));
    let run = |name: &str| {
        let dir = std::env::temp_dir().join(format!("hbfp_nn_wd_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = RunConfig::new("mlp-tinyimg-hbfp8_t8", 20)
            .with_seed(9)
            .with_lr(LrSchedule::Constant { lr: 0.02 })
            .with_checkpoint_every(5)
            .with_max_recoveries(3);
        cfg.checkpoint_dir = Some(dir.clone());
        let trainer = Trainer::with_context(BfpContext::from_env().with_threads(1));
        let mut session = trainer.session(&cfg).unwrap();
        let history = run_resilient(&mut session, &cfg).unwrap();
        let width = session.width();
        let _ = std::fs::remove_dir_all(&dir);
        (history, width)
    };

    let (h, width) = run("a");
    assert_eq!(h.steps.len(), 20, "run must complete after recovery");
    assert!(!h.diverged(), "recovered history must not contain a poisoned step");
    assert_eq!(width, 16, "widened 8 -> 16");
    assert_eq!(h.recoveries.len(), 1);
    let r = &h.recoveries[0];
    assert_eq!(
        r.kind,
        RecoveryKind::StepError,
        "hazard must arrive via the guard trip, not the loss value: {}",
        r.detail
    );
    assert_eq!(r.action, RecoveryAction::Restart, "no checkpoint existed before step 0");
    assert!(r.detail.contains("guard tripped"), "detail: {}", r.detail);
    let g = h.guard.as_ref().expect("session surfaces guard stats");
    assert!(g.nonfinite_inputs >= 1, "scan saw the NaN");
    assert!(g.fp32_fallbacks >= 1, "poisoned GEMM degraded to fp32 instead of aborting");

    // Bitwise determinism across a full detect-rollback-widen cycle.
    let (h2, width2) = run("b");
    assert_eq!(width2, 16);
    let c1: Vec<u32> = h.steps.iter().map(|s| s.loss.to_bits()).collect();
    let c2: Vec<u32> = h2.steps.iter().map(|s| s.loss.to_bits()).collect();
    assert_eq!(c1, c2, "recovery replay must be bitwise deterministic");
}

// -------------------------------------------------- session lifecycle --

#[test]
fn session_state_roundtrips_through_checkpoint_leaves() {
    let _guard = fault::install(FaultInjector::none());
    let trainer = Trainer::with_context(BfpContext::from_env().with_threads(1));
    let cfg = RunConfig::new("mlp-tinyimg-hbfp8_t8", 4).with_seed(3);
    let mut s1 = trainer.session(&cfg).unwrap();
    let mut s2 = trainer.session(&cfg).unwrap();
    // advance s1 a few steps, then clone its state into s2
    for step in 0..3 {
        s1.step(step, 0.02).unwrap();
    }
    let leaves = s1.state();
    assert_eq!(leaves.len(), s1.specs().len());
    s2.restore(&leaves).unwrap();
    // both sessions now step identically (same batch schedule, same state)
    let (l1, _) = s1.step(3, 0.02).unwrap();
    let (l2, _) = s2.step(3, 0.02).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "restored session must replay bit-identically");
    // a truncated leaf vector is rejected
    assert!(s2.restore(&leaves[..leaves.len() - 1]).is_err());
}
