//! Offline stand-in for the XLA/PJRT binding (`xla` crate) that
//! `hbfp::runtime` compiles against.
//!
//! The real binding links the XLA C++ runtime, which is not part of the
//! offline toolchain. This crate mirrors the exact API surface the runtime
//! layer uses — `Literal` construction/reshape/fetch, `PjRtClient`,
//! `HloModuleProto`, `XlaComputation`, `PjRtLoadedExecutable` — so the
//! whole workspace builds and tests standalone. `Literal` is fully
//! functional host-side (it is just typed data + dims); the compile/execute
//! entry points return descriptive errors, which the callers already treat
//! as "artifacts unavailable" and skip (see `rust/tests/*_integration.rs`).
//! Swapping in the real binding is a Cargo.toml-only change.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the binding's: displayable, `std::error::Error`,
/// `Send + Sync` so `anyhow::Context` applies.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("XLA stub: {what} requires the real `xla` binding (see rust/vendor/xla)"))
}

/// Typed storage of a literal. Public only so `NativeType` can name it;
/// treat as private.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a `Literal` can hold (subset: f32, i32).
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side literal: row-major typed data plus dimensions. Fully
/// functional in the stub (no device involved).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Tuple literal (what a multi-output computation returns).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(elements) }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    /// Same data, new dims (must preserve the element count).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".to_string()));
        }
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elements) from {} elements",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn shape_dims(&self) -> &[i64] {
        &self.dims
    }

    /// Split a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("empty literal or element type mismatch".to_string()))
    }
}

/// PJRT client handle. Construction succeeds (so engine plumbing is
/// testable); compilation reports the stub honestly.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {})
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling a computation"))
    }
}

/// Parsed HLO module (opaque).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path:?}")))
    }
}

/// A computation ready to compile (opaque).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A compiled executable (never constructible through the stub's public
/// API today, but the type and methods must exist for callers).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Returns per-replica output buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching a buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape_dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn tuple_split() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn unavailable_paths_error() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let c = XlaComputation::from_proto(&HloModuleProto {});
        assert!(client.compile(&c).is_err());
    }
}
