//! Offline stand-in for the `log` crate: the same facade API (levels,
//! `Log` trait, global logger, `error!`/`warn!`/`info!`/`debug!`/`trace!`
//! macros) for the subset hbfp uses, with no external dependency. The
//! semantics match the real crate — a process-global `&'static dyn Log`
//! plus an atomic max-level filter — so swapping in crates.io `log` is a
//! manifest-only change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single record. Ordered from least (`Error`) to most
/// (`Trace`) verbose, like the real crate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Global verbosity ceiling. `Off` silences everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a record (just the level and target here).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted message arguments.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// The logger interface the application installs.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: filter by the atomic max level, then dispatch to the
/// installed logger (if any) through its `enabled` gate.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    struct Capture(Mutex<Vec<String>>);

    impl Log for Capture {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= Level::Info
        }
        fn log(&self, record: &Record) {
            self.0.lock().unwrap().push(format!("{}: {}", record.level(), record.args()));
        }
        fn flush(&self) {}
    }

    #[test]
    fn ordering_matches_log_crate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info <= Level::Info);
        assert!(Level::Debug > Level::Info);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn dispatch_respects_levels() {
        static CAP: OnceLock<Capture> = OnceLock::new();
        let cap = CAP.get_or_init(|| Capture(Mutex::new(Vec::new())));
        let _ = set_logger(cap);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 42);
        debug!("filtered by max level");
        warn!("warned");
        let lines = cap.0.lock().unwrap().clone();
        assert!(lines.contains(&"INFO: hello 42".to_string()), "{lines:?}");
        assert!(lines.iter().all(|l| !l.contains("filtered")), "{lines:?}");
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
