# Tooling entry points. `make check` is the PR gate: format, release
# build, full test suite. `make perf` regenerates BENCH_bfp_ops.json at
# the repo root (see PERF.md); `make bench-quick` is the 3-rep smoke run
# of the same ladder (also writes the JSON); `make perf-record` is the
# quick run intended for committing the refreshed baseline so PRs leave
# a perf trajectory.

.PHONY: check fmt build test lint examples perf bench-quick perf-record train-smoke

check: fmt build test

fmt:
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

# Lint gate (a CI leg): tests, benches, and examples included, warnings
# denied — uses of the deprecated matmul/quantize zoo outside the
# shim-equivalence test fail here, so the retired API can't re-spread.
lint:
	cargo clippy --all-targets -- -D warnings

examples:
	cargo build --release --examples

perf:
	cargo bench --bench bfp_ops -- --json

bench-quick:
	cargo bench --bench bfp_ops -- --quick --json

perf-record: bench-quick
	@echo "BENCH_bfp_ops.json refreshed — commit it to update the perf baseline"

# Native training smoke (the CI train-smoke job): 50 steps of the paired
# FP32 / HBFP-m8 run at 1 and 4 workers. --max-loss gates on the final
# loss (mean of last 10 steps; ln(10) ~ 2.30 is the random floor, so 2.2
# requires genuine learning), and the example itself asserts the
# plan-cache counters prove GEMMs routed through cached plans.
train-smoke:
	HBFP_THREADS=1 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2
	HBFP_THREADS=4 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2
