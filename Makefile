# Tooling entry points. `make check` is the PR gate: format, release
# build, full test suite. `make perf` regenerates BENCH_bfp_ops.json at
# the repo root (see PERF.md).

.PHONY: check fmt build test perf

check: fmt build test

fmt:
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

perf:
	cargo bench --bench bfp_ops -- --json
