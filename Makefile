# Tooling entry points. `make check` is the PR gate: format, release
# build, full test suite. `make perf` regenerates BENCH_bfp_ops.json at
# the repo root (see PERF.md); `make bench-quick` is the 3-rep smoke run
# of the same ladder (also writes the JSON); `make perf-record` is the
# quick run intended for committing the refreshed baseline so PRs leave
# a perf trajectory.

.PHONY: check fmt build test lint examples perf bench-quick perf-record train-smoke obs-smoke

check: fmt build test

fmt:
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

# Lint gate (a CI leg): tests, benches, and examples included, warnings
# denied — uses of the deprecated matmul/quantize zoo outside the
# shim-equivalence test fail here, so the retired API can't re-spread.
lint:
	cargo clippy --all-targets -- -D warnings

examples:
	cargo build --release --examples

perf:
	cargo bench --bench bfp_ops -- --json

bench-quick:
	cargo bench --bench bfp_ops -- --quick --json

perf-record: bench-quick
	@echo "BENCH_bfp_ops.json refreshed — commit it to update the perf baseline"

# Native training smoke (the CI train-smoke job): 50 steps of the paired
# FP32 / HBFP-m8 run at 1 and 4 workers. --max-loss gates on the final
# loss (mean of last 10 steps; ln(10) ~ 2.30 is the random floor, so 2.2
# requires genuine learning), and the example itself asserts the
# plan-cache counters prove GEMMs routed through cached plans.
train-smoke:
	HBFP_THREADS=1 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2
	HBFP_THREADS=4 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2

# Observability smoke (the CI obs-smoke job). Full telemetry must not
# move a single bit of the training curve: the 50-step run repeats with
# HBFP_OBS=off and =full and the curve CSVs must match exactly once the
# wall-clock secs column is stripped (`cut -f1-5`). Off mode must emit
# no "obs" section at all; full mode must carry the numeric-health
# schema (per-layer SNR/clamp/exponent keys + stage timings). Finishes
# with the obs_demo trace artifact and the obs integration suite
# (thread-invariance, counter conservation).
obs-smoke:
	rm -rf results/obs_smoke && mkdir -p results/obs_smoke
	HBFP_OBS=off HBFP_THREADS=4 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2
	for f in results/e2e_*.csv; do cut -d, -f1-5 "$$f" > "results/obs_smoke/off_$$(basename $$f)"; done
	! grep -q '"obs"' results/e2e_mlp-cifar10like-hbfp8_t24.metrics.json
	HBFP_OBS=full HBFP_THREADS=4 cargo run --release --example train_cifar -- --steps 50 --max-loss 2.2
	for f in results/e2e_*.csv; do cut -d, -f1-5 "$$f" | diff - "results/obs_smoke/off_$$(basename $$f)" || exit 1; done
	for key in '"obs"' '"health"' '"stage_us"' '"stage_totals_us"' '"snr_db"' '"clamp_frac"' '"sat_frac"' '"exp_span"'; do \
		grep -q "$$key" results/e2e_mlp-cifar10like-hbfp8_t24.metrics.json || { echo "obs-smoke: metrics JSON missing $$key"; exit 1; }; done
	HBFP_THREADS=4 cargo run --release --example obs_demo
	test -s results/trace.json && grep -q traceEvents results/trace.json
	cargo test -q --test obs
