# Tooling entry points. `make check` is the PR gate: format, release
# build, full test suite. `make perf` regenerates BENCH_bfp_ops.json at
# the repo root (see PERF.md); `make bench-quick` is the 3-rep smoke run
# of the same ladder (also writes the JSON); `make perf-record` is the
# quick run intended for committing the refreshed baseline so PRs leave
# a perf trajectory.

.PHONY: check fmt build test perf bench-quick perf-record

check: fmt build test

fmt:
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

perf:
	cargo bench --bench bfp_ops -- --json

bench-quick:
	cargo bench --bench bfp_ops -- --quick --json

perf-record: bench-quick
	@echo "BENCH_bfp_ops.json refreshed — commit it to update the perf baseline"
