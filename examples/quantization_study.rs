//! Quantization study — the paper's §4/§4.2 motivation, measured.
//!
//! Generates tensors with the value-distribution shapes that occur in
//! training (activations ~ one scale; gradients spanning many binades;
//! weight matrices with per-filter scale structure) and reports, per
//! mantissa width and tile size: exponent span, SNR, and the fraction of
//! values flushed to zero — the numbers behind "exponent sharing may lead
//! to data loss" and "tiling bounds the number of values that share
//! exponents".
//!
//!     cargo run --release --example quantization_study

use hbfp::bfp::{quant_report, tile_spans, ExponentStats, TileSize};
use hbfp::util::rng::SplitMix64;

fn gen_activation_like(rng: &mut SplitMix64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal().abs()).collect() // post-ReLU-ish
}

fn gen_gradient_like(rng: &mut SplitMix64, rows: usize, cols: usize) -> Vec<f32> {
    // per-row scale spread over ~6 orders of magnitude: late-training
    // gradients (deep layers vs head) — the regime that kills FP16 (§3)
    let mut v = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let scale = 10f32.powf(-6.0 * r as f32 / rows as f32);
        for c in 0..cols {
            v[r * cols + c] = rng.normal() * scale;
        }
    }
    v
}

fn gen_weight_like(rng: &mut SplitMix64, rows: usize, cols: usize) -> Vec<f32> {
    // per-column (output-filter) scales over ~1.5 orders of magnitude
    let mut v = vec![0.0f32; rows * cols];
    for c in 0..cols {
        let scale = 10f32.powf(-1.5 * (c % 8) as f32 / 8.0);
        for r in 0..rows {
            v[r * cols + c] = rng.normal() * scale * 0.05;
        }
    }
    v
}

fn study(name: &str, data: &[f32], rows: usize, cols: usize) {
    let st = ExponentStats::of(data);
    println!(
        "\n--- {name} ({rows}x{cols}): exponent span {} binades, zeros {:.1}% ---",
        st.span(),
        st.zero_frac * 100.0
    );
    println!(
        "{:<10} {:<14} {:>10} {:>12} {:>12}",
        "mantissa", "tile", "SNR dB", "flushed %", "max rel err"
    );
    for &m in &[4u32, 8, 12] {
        for &tile in &[TileSize::Whole, TileSize::Edge(64), TileSize::Edge(24), TileSize::Edge(8)] {
            let r = quant_report(data, rows, cols, m, tile).unwrap();
            let tname = match tile {
                TileSize::Whole => "whole".to_string(),
                TileSize::Edge(t) => format!("{t}x{t}"),
            };
            println!(
                "{:<10} {:<14} {:>10.1} {:>11.2}% {:>12.4}",
                m,
                tname,
                r.snr_db,
                r.underflow_frac * 100.0,
                r.max_rel_err
            );
        }
    }
    let spans = tile_spans(data, rows, cols, 24);
    let max_span = spans.iter().max().copied().unwrap_or(0);
    let mean_span = spans.iter().sum::<i32>() as f64 / spans.len().max(1) as f64;
    println!("per-24x24-tile spans: mean {mean_span:.1}, max {max_span} (vs whole {})", st.span());
}

fn main() {
    let mut rng = SplitMix64::new(42);
    let act = gen_activation_like(&mut rng, 96 * 96);
    study("activations (post-ReLU)", &act, 96, 96);

    let grad = gen_gradient_like(&mut rng, 96, 96);
    study("gradients (6-decade spread)", &grad, 96, 96);

    let w = gen_weight_like(&mut rng, 96, 96);
    study("weights (per-filter scales)", &w, 96, 96);

    println!(
        "\nReading: gradients are the case the paper designs for — whole-tensor\n\
         exponents flush a large fraction of values at 8-bit mantissas, while\n\
         24x24 tiles keep the flushed fraction near zero. Dot products tolerate\n\
         the residual loss (reductions are max-dominated); elementwise ops would\n\
         not, which is exactly the hybrid split (§4.1)."
    );
}
