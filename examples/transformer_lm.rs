//! Extension experiment: HBFP on attention. Trains the decoder-only
//! transformer LM under fp32 / hbfp8_16 / hbfp12_16 (weight matmuls
//! quantized — "HBFP-W", see python/compile/models/transformer.py) and
//! reports validation perplexity, answering the paper's natural follow-up:
//! does the hybrid scheme survive attention blocks?
//!
//!     cargo run --release --example transformer_lm [-- --steps 300]

use std::sync::Arc;

use anyhow::Result;
use hbfp::coordinator::{LrSchedule, RunConfig, Trainer};
use hbfp::runtime::Manifest;
use hbfp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 300)?;
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let trainer = Trainer::new(manifest)?;

    println!("== extension: HBFP-W transformer LM on ptblike, {steps} steps ==");
    let mut rows = Vec::new();
    for combo in [
        "transformer_mini-ptblike-fp32",
        "transformer_mini-ptblike-hbfp8_16_t24",
        "transformer_mini-ptblike-hbfp12_16_t24",
    ] {
        let cfg = RunConfig::new(combo, steps)
            .with_lr(LrSchedule::Cosine { base: 0.3, floor: 0.003, total: steps })
            .with_eval_every((steps / 6).max(1));
        let r = trainer.run(&cfg)?;
        println!("\n{combo}:");
        for ev in &r.history.evals {
            println!("  step {:>4}: val ppl {:.3}", ev.step, ev.loss.exp());
        }
        rows.push((combo, r.final_loss.exp(), r.diverged));
    }
    println!("\nsummary (validation perplexity):");
    let base = rows[0].1;
    for (combo, ppl, div) in &rows {
        let tag = if *div { " DIVERGED" } else { "" };
        println!("  {combo:<50} ppl {ppl:.3} ({:+.2}% vs fp32){tag}", (ppl / base - 1.0) * 100.0);
    }
    Ok(())
}
