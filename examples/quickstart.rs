//! Quickstart: the smallest end-to-end HBFP run, exercising all three
//! layers — the *Pallas* BFP matmul kernel (L1) lowered inside the MLP
//! train step (L2), executed from the rust trainer (L3).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Trains the MLP on the synthetic CIFAR-10-like task twice — FP32 baseline
//! and hbfp8_16 via the Pallas kernel — and prints both loss curves.
//!
//! For the inference side of the stack — resident quantized weights,
//! micro-batching, admission control, and graceful precision degradation
//! under overload — see `cargo run --release --example serve_demo`.
//!
//! To watch the numerics and timing as they happen, run any example with
//! `HBFP_OBS=full` (per-layer exponent/SNR health, stage timings), or
//! `cargo run --release --example obs_demo` for a guided tour that also
//! writes `results/trace.json` for chrome://tracing / ui.perfetto.dev.
//! See PERF.md § Observability.

use std::sync::Arc;

use anyhow::Result;
use hbfp::coordinator::{LrSchedule, RunConfig, Trainer};
use hbfp::runtime::Manifest;

fn main() -> Result<()> {
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let trainer = Trainer::new(manifest)?;
    let steps = 60;

    println!("== HBFP quickstart: MLP on cifar10like, fp32 vs hbfp8_16 (Pallas kernel) ==\n");
    let mut finals = Vec::new();
    for combo in ["mlp-cifar10like-fp32", "mlp-cifar10like-hbfpp8_16_t24"] {
        let cfg = RunConfig::new(combo, steps)
            .with_lr(LrSchedule::Constant { lr: 0.1 })
            .with_eval_every(20);
        let r = trainer.run(&cfg)?;
        println!("{combo}:");
        for s in r.history.steps.iter().step_by(2) {
            println!("  step {:>3}  loss {:.4}  acc {:.2}", s.step, s.loss, s.acc);
        }
        println!(
            "  final: val err {:.2}%  ({:.1} steps/s)\n",
            r.final_error * 100.0,
            r.history.throughput().unwrap_or(0.0)
        );
        finals.push((combo, r.final_error));
    }
    let gap = (finals[1].1 - finals[0].1).abs() * 100.0;
    println!("fp32 vs hbfp8_16 val-error gap: {gap:.2}pp — the paper's claim is that this is small.");
    Ok(())
}
