//! Observability walkthrough: run a short MLP training burst and a
//! serving burst with `HBFP_OBS=full`, then dump everything the layer
//! collected — the per-layer numeric-health timeline (block-exponent
//! spread, clamp/saturation rates, quantization SNR), per-step stage
//! timings, the unified metrics registry (guard + plan cache + dataset
//! cache + datapath counters + pool lanes), and a chrome://tracing
//! trace file.
//!
//!     cargo run --release --example obs_demo
//!
//! Artifacts:
//!
//!     results/trace.json   load in chrome://tracing or ui.perfetto.dev
//!
//! Knobs:
//!
//!     HBFP_THREADS=4      worker budget (pool lane timing shows up >1)
//!     HBFP_SIMD=off       pin the scalar kernel family
//!
//! The demo forces full mode in code; the same telemetry comes out of
//! any binary in the repo by exporting `HBFP_OBS=full` (see PERF.md
//! § Observability for the span naming convention and overhead budget).

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use hbfp::bfp::{export_datapath_counters, BfpContext, TileSize};
use hbfp::coordinator::{LrSchedule, RunConfig};
use hbfp::nn::Trainer;
use hbfp::obs::{self, trace, ObsMode, Registry};
use hbfp::serve::{InferenceServer, ManualClock, ServeConfig};
use hbfp::util::fault::{self, FaultInjector};

fn main() -> Result<()> {
    // Full telemetry without requiring the env var; a clean injector so
    // the burst is deterministic.
    obs::set_mode(ObsMode::Full);
    let _quiet = fault::install(FaultInjector::none());

    // ---- training burst -------------------------------------------------
    println!("== training burst: mlp-tinyimg-hbfp8_t8, 60 steps ==");
    let trainer = Trainer::with_context(BfpContext::from_env());
    let cfg = RunConfig::new("mlp-tinyimg-hbfp8_t8", 60)
        .with_seed(5)
        .with_lr(LrSchedule::Constant { lr: 0.02 });
    let report = trainer.run(&cfg)?;
    println!(
        "final loss {:.4}, eval error {:?}, plan cache {}h/{}m",
        report.final_loss, report.final_eval_error, report.plan_hits, report.plan_misses
    );

    let obs_json = report.obs.as_ref().expect("full mode collects per-layer health");
    if let Some(health) = obs_json.get("health") {
        println!("\nper-layer numeric health (last sample per layer):");
        if let hbfp::util::json::Json::Obj(layers) = health {
            for (layer, rows) in layers {
                if let Some(last) = rows.as_arr().and_then(|r| r.last()) {
                    println!(
                        "  {layer}: exp span {}, clamp {:.4}, saturated tiles {:.4}, \
                         snr {:.1} dB",
                        last.get("exp_span").and_then(|v| v.as_i64()).unwrap_or(0),
                        last.get("clamp_frac").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        last.get("sat_frac").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        last.get("snr_db").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    );
                }
            }
        }
    }
    if let Some(totals) = obs_json.get("stage_totals_us") {
        println!("stage totals (us): {totals}");
    }

    // ---- serving burst --------------------------------------------------
    println!("\n== serving burst: one tenant, 12 waves ==");
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(ServeConfig::default(), ctx, clock);
    let (k, n) = (64, 64);
    let weights: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.173).sin() * 0.5).collect();
    let model = srv.register_model("tenant-a", &weights, k, n)?;
    for wave in 0..12u64 {
        for j in 0..3u64 {
            let x: Vec<f32> =
                (0..k).map(|c| ((c as f32) * 0.31 + (wave * 3 + j) as f32 * 0.77).cos()).collect();
            srv.submit(model, x, None)?;
        }
        srv.pump()?;
    }
    srv.begin_drain(10_000)?;
    let drain = srv.run_until_stopped()?;
    let served = srv.metrics().completed;
    println!("served {served} requests, drained in {} pumps", drain.pumps);

    // ---- the unified registry snapshot ----------------------------------
    let reg = Registry::new();
    if let Some(g) = &report.history.guard {
        g.export_metrics(&reg, "train.guard");
    }
    srv.metrics().export_metrics(&reg, "serve");
    srv.plan_cache().export_metrics(&reg, "serve.plan_cache");
    trainer.dataset_cache().export_metrics(&reg, "train.dataset_cache");
    export_datapath_counters(&reg);
    println!("\n== registry snapshot ==\n{}", reg.to_json());

    // Pool lane busy/idle timing accumulates in the process-global
    // registry (only populated when the pool actually spun up workers).
    let global = obs::registry::global();
    if !global.is_empty() {
        println!("\n== global registry (pool lanes) ==\n{}", global.to_json());
    }

    // ---- trace export ---------------------------------------------------
    let trace_path = Path::new("results/trace.json");
    trace::write_chrome_trace(trace_path)?;
    let (events, dropped) = trace::snapshot();
    println!(
        "\nwrote {} span events ({dropped} dropped at ring capacity) to {}",
        events.len(),
        trace_path.display()
    );
    println!("open chrome://tracing (or https://ui.perfetto.dev) and load the file");
    Ok(())
}
