//! Language-modeling example (Table 3 workload): train the LSTM char-LM on
//! the synthetic Markov corpus under FP32 and HBFP and report validation
//! perplexity against the corpus's true entropy floor.
//!
//!     cargo run --release --example lm_char [-- --steps 300]

use std::sync::Arc;

use anyhow::Result;
use hbfp::coordinator::{LrSchedule, RunConfig, Trainer};
use hbfp::data::TextDataset;
use hbfp::runtime::Manifest;
use hbfp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 300)?;
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);

    // Report the task's perplexity floor so numbers are interpretable.
    let ds = TextDataset::generate(32, 48, 0 ^ 0xda7a, 60_000, 12_000);
    println!(
        "corpus: vocab 32, order-2 Markov, entropy floor = {:.3} nats (ppl {:.2})",
        ds.entropy_nats,
        ds.entropy_nats.exp()
    );

    let trainer = Trainer::new(manifest)?;
    let mut results = Vec::new();
    for combo in ["lstm-ptblike-fp32", "lstm-ptblike-hbfp8_16_t24", "lstm-ptblike-hbfp12_16_t24"] {
        let cfg = RunConfig::new(combo, steps)
            .with_lr(LrSchedule::Constant { lr: 0.5 })
            .with_eval_every((steps / 6).max(1));
        let r = trainer.run(&cfg)?;
        println!("\n{combo}:");
        for ev in &r.history.evals {
            println!("  step {:>4}: val ppl {:.3}", ev.step, ev.loss.exp());
        }
        results.push((combo, r.final_loss.exp()));
    }

    println!("\nTable-3-style summary (validation perplexity):");
    let base = results[0].1;
    for (combo, ppl) in &results {
        println!("  {combo:<40} ppl {ppl:.3}  ({:+.2}% vs fp32)", (ppl / base - 1.0) * 100.0);
    }
    Ok(())
}
