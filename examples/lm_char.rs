//! Language-modeling example (Table 3 workload class) on the native `nn`
//! subsystem: train the char-LM (embedding → Elman RNN → vocab head) on
//! the synthetic Markov corpus under FP32 and HBFP, report validation
//! perplexity against the corpus's true entropy floor, and write curves
//! plus metrics JSON to `results/lm_*` — no Python, no compiled
//! artifacts.
//!
//!     cargo run --release --example lm_char [-- --steps 300 --seed 11]

use anyhow::Result;
use hbfp::coordinator::{LrSchedule, RunConfig};
use hbfp::nn::Trainer;
use hbfp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 300)?;
    let seed = args.opt_u64("seed", 11)?;
    let trainer = Trainer::new();
    std::fs::create_dir_all("results")?;

    let mut results = Vec::new();
    let mut floor: Option<f64> = None;
    for combo in
        ["charlm-ptblike-fp32", "charlm-ptblike-hbfp8_t24", "charlm-ptblike-hbfp12_t24"]
    {
        let cfg = RunConfig::new(combo, steps)
            .with_seed(seed)
            .with_lr(LrSchedule::Constant { lr: 0.3 })
            .with_eval_every((steps / 6).max(1))
            .with_max_recoveries(2);
        let r = trainer.run(&cfg)?;
        if floor.is_none() {
            floor = r.entropy_floor_nats;
            if let Some(f) = floor {
                println!(
                    "corpus: order-2 Markov, entropy floor = {f:.3} nats (ppl {:.2})",
                    f.exp()
                );
            }
        }
        let csv = format!("results/lm_{combo}.csv");
        r.history.write_csv(std::path::Path::new(&csv))?;
        let metrics = format!("results/lm_{combo}.metrics.json");
        std::fs::write(&metrics, format!("{}\n", r.summary_json()))?;
        println!(
            "\n{combo}: curve -> {csv} ({} steps, {:.1} steps/s, plan cache {} hits)",
            r.history.steps.len(),
            r.history.throughput().unwrap_or(0.0),
            r.plan_hits,
        );
        for ev in &r.history.evals {
            println!("  step {:>4}: val ppl {:.3}", ev.step, ev.loss.exp());
        }
        results.push((combo, r.final_eval_loss.unwrap_or(f32::NAN).exp()));
    }

    println!("\nTable-3-style summary (validation perplexity):");
    let base = results[0].1;
    for (combo, ppl) in &results {
        print!("  {combo:<28} ppl {ppl:.3} ({:+.2}% vs fp32)", (ppl / base - 1.0) * 100.0);
        match floor {
            Some(f) => println!("  floor {:.3}", f.exp()),
            None => println!(),
        }
    }
    Ok(())
}
