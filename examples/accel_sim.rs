//! Accelerator-model example: size the Figure-2 design for each MAC format
//! on a Stratix-V-class budget, then run the cycle-level simulator over the
//! actual GEMM shapes of the wrn_mini forward pass to report *achieved*
//! (not just peak) throughput and MAC utilization per layer.
//!
//!     cargo run --release --example accel_sim

use anyhow::Result;
use hbfp::accel::{size_design, AccelConfig, Accelerator, MacFormat};
use hbfp::util::rng::SplitMix64;

fn main() -> Result<()> {
    // Part 1: the area/throughput table (§6).
    hbfp::coordinator::repro::throughput();

    // Part 2: achieved throughput on real layer shapes (im2col GEMMs of
    // wrn_mini on 16x16 inputs, batch 32).
    let layers: &[(&str, usize, usize, usize)] = &[
        // (name, M = B*H*W, K = Cin*k*k, N = Cout)
        ("stem 3x3x3->16", 32 * 16 * 16, 27, 16),
        ("s0 3x3x16->16", 32 * 16 * 16, 144, 16),
        ("s1 3x3x16->32 /2", 32 * 8 * 8, 144, 32),
        ("s1 3x3x32->32", 32 * 8 * 8, 288, 32),
        ("s2 3x3x32->64 /2", 32 * 4 * 4, 288, 64),
        ("s2 3x3x64->64", 32 * 4 * 4, 576, 64),
        ("fc 64->20", 32, 64, 20),
    ];

    println!("\nAchieved throughput on wrn_mini layer GEMMs (BFP8 array):");
    println!(
        "{:<20} {:>8} {:>8} {:>6} {:>10} {:>12} {:>10}",
        "layer", "M", "K", "N", "cycles", "TOp/s", "util"
    );
    let mut acc = Accelerator::new(AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
    let mut rng = SplitMix64::new(0);
    let mut tot_cycles = 0u64;
    let mut tot_macs = 0u64;
    // The training-step shape: per layer, weights are quantized +
    // panel-packed once (`load_weights`, which also caches the layer's
    // MatmulPlan) and activations stream against them into one reused
    // output buffer — no per-step policy work or output allocation.
    let mut out = Vec::new();
    for &(name, m, k, n) in layers {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        acc.load_weights(&b, k, n, 8)?;
        let stats = acc.gemm_resident_into(&a, m, &mut out)?;
        tot_cycles += stats.cycles;
        tot_macs += stats.macs_used;
        println!(
            "{name:<20} {m:>8} {k:>8} {n:>6} {:>10} {:>12.3} {:>9.1}%",
            stats.cycles,
            stats.effective_ops / 1e12,
            stats.utilization * 100.0
        );
    }
    let peak = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
    let secs = tot_cycles as f64 / 200e6;
    println!(
        "\nwhole fwd pass: {:.3} TOp/s achieved vs {:.3} peak ({:.0}% of roofline)",
        2.0 * tot_macs as f64 / secs / 1e12,
        peak.peak_ops / 1e12,
        2.0 * tot_macs as f64 / secs / peak.peak_ops * 100.0
    );
    println!("(narrow layers with K << array edge underfill the systolic array — the\n same utilization cliff the paper's tiling discussion is about)");
    Ok(())
}
