//! End-to-end training driver (the EXPERIMENTS.md validation run), now on
//! the native `nn` subsystem: train the MLP on the synthetic
//! CIFAR-10-like dataset under FP32 and HBFP-m8, with every GEMM of both
//! forward and backward passes routed through cached BFP matmul plans
//! (the paper's hybrid split), and write the paired loss/validation
//! curves to `results/e2e_*.csv` plus per-run metrics JSON (plan-cache
//! counters included) — no Python, no compiled artifacts.
//!
//!     cargo run --release --example train_cifar [-- --steps 400 --seed 11 --max-loss 2.2]
//!
//! This is the paper's core claim (Figure 3 / Table 2) at one workload:
//! the HBFP-m8 loss curve should track FP32 closely. `--max-loss` turns
//! the run into a smoke gate: the run fails unless every combo's final
//! loss (mean over the last 10 steps) is at or below the threshold.

use anyhow::{anyhow, ensure, Result};
use hbfp::coordinator::{LrSchedule, RunConfig};
use hbfp::nn::Trainer;
use hbfp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 400)?;
    let seed = args.opt_u64("seed", 11)?;
    let max_loss: Option<f32> = match args.opt("max-loss") {
        Some(s) => Some(s.parse().map_err(|_| anyhow!("bad --max-loss {s:?}"))?),
        None => None,
    };
    let trainer = Trainer::new();
    std::fs::create_dir_all("results")?;

    println!("== native e2e: mlp on cifar10like, {steps} steps, seed {seed} ==");
    let mut rows = Vec::new();
    for combo in ["mlp-cifar10like-fp32", "mlp-cifar10like-hbfp8_t24"] {
        let cfg = RunConfig::new(combo, steps)
            .with_seed(seed)
            .with_lr(LrSchedule::default_for(steps, 0.05))
            .with_eval_every((steps / 8).max(1))
            .with_max_recoveries(2);
        let r = trainer.run(&cfg)?;
        let csv = format!("results/e2e_{combo}.csv");
        r.history.write_csv(std::path::Path::new(&csv))?;
        let metrics = format!("results/e2e_{combo}.metrics.json");
        std::fs::write(&metrics, format!("{}\n", r.summary_json()))?;
        println!("\n{combo}: {} train records", r.history.steps.len());
        println!("  curve -> {csv}\n  metrics -> {metrics}");
        for ev in &r.history.evals {
            println!(
                "  eval @ step {:>4}: loss {:.4}  err {:.2}%",
                ev.step,
                ev.loss,
                ev.error * 100.0
            );
        }
        println!(
            "  wall {:.1}s ({:.1} steps/s)  plan cache {} hits / {} misses  dataset {}  width {} bits",
            r.train_secs,
            r.history.throughput().unwrap_or(0.0),
            r.plan_hits,
            r.plan_misses,
            if r.dataset_cache_hit { "reused" } else { "generated" },
            r.final_width_bits,
        );
        if combo.contains("hbfp") {
            ensure!(
                r.plan_hits > 0,
                "{combo}: plan cache never hit — GEMMs are not routed through cached plans"
            );
        }
        if let Some(cap) = max_loss {
            ensure!(
                r.final_loss.is_finite() && r.final_loss <= cap,
                "{combo}: final loss {} above the --max-loss gate {cap}",
                r.final_loss
            );
        }
        rows.push((combo, r.final_loss, r.final_eval_error));
    }

    println!("\nsummary (paired curves, final loss = mean of last 10 steps):");
    let base = rows[0].1;
    for (combo, loss, err) in &rows {
        let gap = (loss / base - 1.0) * 100.0;
        let err_s = err.map(|e| format!("{:.2}%", e * 100.0)).unwrap_or_else(|| "-".into());
        println!("  {combo:<28} final loss {loss:.4} ({gap:+.2}% vs fp32)  val err {err_s}");
    }
    Ok(())
}
