//! End-to-end training driver (the EXPERIMENTS.md validation run): train
//! the WRN-mini CNN on the synthetic CIFAR-100-like dataset for several
//! hundred steps under FP32 and HBFP, logging the full loss curve and
//! periodic validation error, and writing the series to results/e2e_*.csv.
//!
//!     cargo run --release --example train_cifar [-- --steps 400]
//!
//! This is the paper's core experiment (Figure 3 left / Table 2) at one
//! workload: HBFP with 8-bit dot-product mantissas + 16-bit weight storage
//! should track the FP32 loss curve and land within ~1pp validation error.

use std::sync::Arc;

use anyhow::Result;
use hbfp::coordinator::{LrSchedule, RunConfig, Trainer};
use hbfp::runtime::Manifest;
use hbfp::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 400)?;
    let manifest = Arc::new(Manifest::load(std::path::Path::new("artifacts"))?);
    let trainer = Trainer::new(manifest)?;
    std::fs::create_dir_all("results")?;

    println!("== end-to-end: wrn_mini on cifar100like, {steps} steps ==");
    let mut rows = Vec::new();
    for combo in [
        "wrn_mini-cifar100like-fp32",
        "wrn_mini-cifar100like-hbfp8_16_t24",
        "wrn_mini-cifar100like-hbfp12_16_t24",
    ] {
        let cfg = RunConfig::new(combo, steps)
            .with_lr(LrSchedule::default_for(steps, 0.05))
            .with_eval_every((steps / 8).max(1));
        let t0 = std::time::Instant::now();
        let r = trainer.run(&cfg)?;
        let path = format!("results/e2e_{combo}.csv");
        r.history.write_csv(std::path::Path::new(&path))?;
        println!(
            "\n{combo}: {} train records, curve -> {path}",
            r.history.steps.len()
        );
        for ev in &r.history.evals {
            println!("  eval @ step {:>4}: loss {:.4}  err {:.2}%", ev.step, ev.loss, ev.error * 100.0);
        }
        println!(
            "  wall {:.1}s  ({:.1} steps/s, compile {:.1}s)",
            t0.elapsed().as_secs_f64(),
            r.history.throughput().unwrap_or(0.0),
            r.compile_secs
        );
        rows.push((combo, r.final_error, r.final_loss));
    }

    println!("\nsummary (val error):");
    let base = rows[0].1;
    for (combo, err, loss) in &rows {
        println!(
            "  {combo:<44} err {:>6.2}%  loss {loss:.4}  gap {:+.2}pp",
            err * 100.0,
            (err - base) * 100.0
        );
    }
    Ok(())
}
