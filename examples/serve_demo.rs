//! Serving walkthrough: drive the resilient multi-tenant front-end
//! through an overload burst and watch the machinery work — fair-share
//! scheduling between a flooding tenant and a well-behaved one,
//! backpressure, deadline expiry, graceful precision degradation
//! (16 -> 8 bits), contained worker faults, a mid-burst hot weight
//! reload (one clean swap, one garbled rollback), and a graceful drain
//! to `Stopped` — then dump the full metrics JSON.
//!
//!     cargo run --release --example serve_demo
//!
//! Knobs (all optional):
//!
//!     HBFP_FAULT=worker-panic:0.3:11,reload-garble:1.0:7
//!                         run under the env harness instead of the
//!                         demo's default mixed injector
//!     HBFP_THREADS=4      worker budget (1 = inline, no pool faults)
//!     HBFP_SIMD=off       pin the scalar kernel family
//!
//! The same scenarios run deterministically (manual clock, fixed seeds,
//! replayed twice) in `tests/serve.rs`: the single-tenant overload soak,
//! the two-tenant flood soak, and the lifecycle (reload + drain) tests.

use std::sync::Arc;

use anyhow::Result;
use hbfp::bfp::{BfpContext, TileSize};
use hbfp::serve::{
    BreakerConfig, InferenceServer, ManualClock, Outcome, ServeConfig, Submission,
};
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};

fn main() -> Result<()> {
    let cfg = ServeConfig {
        queue_capacity: 32,
        elevated_depth: 8,
        degrade_depth: 12,
        shed_depth: 24,
        max_batch_rows: 16,
        // a quarter-batch quantum: the scheduler interleaves tenants
        // several times per backlog instead of serving head-of-line
        drr_quantum_rows: 4,
        full_bits: 16,
        degraded_bits: 8,
        default_deadline_ticks: 50_000,
        est_ticks_per_row: 200,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        max_gemm_retries: 2,
        breaker: BreakerConfig::default(),
    };
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());

    let (k, n) = (256, 256);
    let weights: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.173).sin() * 0.5).collect();
    let weights_v2: Vec<f32> = weights.iter().map(|w| w * 0.8 - 0.05).collect();
    // Residency building is not inside the serve loop's containment, so
    // it always runs shielded from fault injection.
    let (flood, steady) = {
        let _quiet = fault::install(FaultInjector::none());
        (
            srv.register_model_with_share("tenant-a", &weights, k, n, 2)?,
            srv.register_model_with_share("tenant-b", &weights, k, n, 1)?,
        )
    };
    for idx in [flood, steady] {
        let m = srv.model(idx).unwrap();
        println!(
            "resident model: {} ({}x{}), share {}, {} bytes across 16- and 8-bit copies",
            m.name(),
            k,
            n,
            srv.metrics().models[idx].share,
            m.heap_bytes()
        );
    }

    // Honor an env-armed injector; otherwise install the demo's default
    // mixed fault load (same spec as the CI overload-soak leg).
    let _guard = if fault::active().armed() {
        println!("faults: honoring HBFP_FAULT from the environment");
        None
    } else {
        println!(
            "faults: worker-panic:0.35 slow-worker:0.5 nan-activation:0.05 \
             slow-request:0.25 tenant-flood:0.4"
        );
        Some(fault::install(FaultInjector::from_specs(&[
            FaultSpec { site: FaultSite::WorkerPanic, rate: 0.35, seed: 11 },
            FaultSpec { site: FaultSite::SlowWorker, rate: 0.5, seed: 11 },
            FaultSpec { site: FaultSite::NanActivation, rate: 0.05, seed: 11 },
            FaultSpec { site: FaultSite::SlowRequest, rate: 0.25, seed: 11 },
            FaultSpec { site: FaultSite::TenantFlood, rate: 0.4, seed: 11 },
        ])))
    };

    // Overload burst: tenant A floods at ~5x tenant B's rate (plus any
    // deterministic `tenant-flood` spikes the injector fires), B carries
    // real deadlines, a poisoned payload rides along every 13th request.
    println!("\nburst: 18 waves, A floods 5-8x B, pump once per wave");
    let mut submitted = 0u64;
    for wave in 0..18u64 {
        let spike = if fault::fire(FaultSite::TenantFlood) { 3 } else { 0 };
        for j in 0..5 + spike {
            let i = wave * 10 + j;
            let mut x: Vec<f32> =
                (0..k).map(|c| ((c as f32) * 0.31 + i as f32 * 0.77).cos()).collect();
            if i % 13 == 12 {
                x[2] = f32::NAN;
            }
            if let Submission::Rejected(why) = srv.submit(flood, x, None)? {
                if wave % 4 == 0 && j == 0 {
                    println!(
                        "  wave {wave}: tenant-a rejected ({why}) at depth {}",
                        srv.model_queue_depth(flood)
                    );
                }
            }
            submitted += 1;
        }
        let xb: Vec<f32> =
            (0..k).map(|c| ((c as f32) * 0.19 + wave as f32 * 1.3).sin()).collect();
        srv.submit(steady, xb, Some(6_000))?;
        submitted += 1;

        // Mid-burst lifecycle events: a garbled reload that must roll
        // back (wave 6), then a clean reload that swaps to generation 1
        // without touching in-flight work (wave 9).
        if wave == 6 {
            let garble = fault::install(FaultInjector::from_specs(&[FaultSpec {
                site: FaultSite::ReloadGarble,
                rate: 1.0,
                seed: 7,
            }]));
            match srv.reload_model(flood, &weights_v2) {
                Err(e) => println!("  wave 6: garbled reload rolled back: {e}"),
                Ok(_) => println!("  wave 6: reload unexpectedly validated"),
            }
            drop(garble);
            println!(
                "  wave 6: tenant-a still serving generation {}",
                srv.model(flood).unwrap().generation()
            );
        }
        if wave == 9 {
            match srv.reload_model(flood, &weights_v2) {
                Ok(r) => println!(
                    "  wave 9: hot reload swapped generation {} -> {} (validated at {:?})",
                    r.old_generation, r.new_generation, r.validated_widths
                ),
                Err(e) => println!("  wave 9: reload failed under env faults: {e}"),
            }
        }

        let rep = srv.pump()?;
        if let Some(b) = rep.batch {
            if b.degraded || b.split_fallback {
                println!(
                    "  batch: model {} x{} rows @ {} bits gen {}{}{}",
                    b.model,
                    b.ids.len(),
                    b.bits,
                    b.generation,
                    if b.degraded { " [degraded]" } else { "" },
                    if b.split_fallback { " [split-fallback]" } else { "" },
                );
            }
        }
    }

    // Graceful shutdown: stop admission, pump out what fits inside the
    // drain window, force-expire the rest, land on Stopped.
    let deadline = srv.begin_drain(2_000)?;
    println!("\ndraining: deadline at tick {deadline}, ready={}", srv.is_ready());
    if let Submission::Rejected(why) = srv.submit(steady, vec![0.25; k], None)? {
        println!("  new work refused while draining: {why}");
    }
    submitted += 1;
    let drain = srv.run_until_stopped()?;
    println!(
        "  drained in {} pumps: {} served, {} expired, {} force-expired, {} failed, conserved={}",
        drain.pumps, drain.served, drain.expired, drain.force_expired, drain.failed,
        drain.conserved
    );

    let mut served = 0usize;
    let mut degraded = 0usize;
    let mut expired = 0usize;
    let mut failed = 0usize;
    for c in srv.drain_completions() {
        match c.outcome {
            Outcome::Served(r) => {
                served += 1;
                if r.degraded {
                    degraded += 1;
                }
            }
            Outcome::Expired(_) => expired += 1,
            Outcome::Failed(_) => failed += 1,
        }
    }
    let m = srv.metrics();
    println!(
        "\noutcomes: {served} served ({degraded} degraded), {expired} expired, {failed} failed \
         of {submitted} submitted"
    );
    println!(
        "rejected: {} (queue-full {}, overloaded {}, shedding {}, quarantined {}, draining {})",
        m.rejected_total(),
        m.rejected_queue_full,
        m.rejected_overloaded,
        m.rejected_shedding,
        m.rejected_quarantined,
        m.rejected_draining
    );
    println!(
        "faults: {} panics contained, {} retries, {} split fallbacks, {} slow requests; \
         breaker trips {} / recoveries {}; reloads {} / rollbacks {}",
        m.panics_contained,
        m.gemm_retries,
        m.split_fallbacks,
        m.slow_requests,
        m.breaker_trips,
        m.breaker_recoveries,
        m.reloads,
        m.reload_rollbacks
    );
    for t in &m.models {
        println!(
            "tenant {}: share {}, admitted {}, served {} ({} degraded), expired {}, failed {}, \
             quarantined {}, p99 {}",
            t.name,
            t.share,
            t.admitted,
            t.served,
            t.degraded,
            t.expired,
            t.failed,
            t.quarantined,
            t.latency.p99()
        );
    }
    println!(
        "latency ticks: p50 {} p95 {} p99 {} max {} over {} served",
        m.latency.p50(),
        m.latency.p95(),
        m.latency.p99(),
        m.latency.max(),
        m.latency.count()
    );

    println!("\nmetrics json:\n{}", srv.metrics_json());
    Ok(())
}
