//! Serving walkthrough: drive the resilient inference front-end through
//! an overload burst and watch the ladder work — backpressure, deadline
//! expiry, graceful precision degradation (16 -> 8 bits), and contained
//! worker faults — then dump the full metrics JSON.
//!
//!     cargo run --release --example serve_demo
//!
//! Knobs (all optional):
//!
//!     HBFP_FAULT=worker-panic:0.3:11,slow-request:0.25:11
//!                         run under the env harness instead of the
//!                         demo's default mixed injector
//!     HBFP_THREADS=4      worker budget (1 = inline, no pool faults)
//!     HBFP_SIMD=off       pin the scalar kernel family
//!
//! The same scenario runs deterministically (manual clock, fixed seeds,
//! replayed twice) as `tests/serve.rs::overload_soak_is_deterministic_...`.

use std::sync::Arc;

use anyhow::Result;
use hbfp::bfp::{BfpContext, TileSize};
use hbfp::serve::{InferenceServer, ManualClock, Outcome, ServeConfig, Submission};
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};

fn main() -> Result<()> {
    let cfg = ServeConfig {
        queue_capacity: 32,
        elevated_depth: 8,
        degrade_depth: 12,
        shed_depth: 24,
        max_batch_rows: 16,
        full_bits: 16,
        degraded_bits: 8,
        default_deadline_ticks: 50_000,
        est_ticks_per_row: 200,
        synthetic_ticks_per_row: 100,
        slow_request_penalty_ticks: 500,
        max_gemm_retries: 2,
    };
    let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
    let clock = Arc::new(ManualClock::new());
    let mut srv = InferenceServer::new(cfg, ctx, clock.clone());

    let (k, n) = (256, 256);
    let weights: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.173).sin() * 0.5).collect();
    // Residency building is not inside the serve loop's containment, so
    // it always runs shielded from fault injection.
    let model = {
        let _quiet = fault::install(FaultInjector::none());
        srv.register_model("demo-256x256", &weights, k, n)?
    };
    println!(
        "resident model: {} ({}x{}), {} bytes across 16- and 8-bit copies",
        srv.model(model).unwrap().name(),
        k,
        n,
        srv.model(model).unwrap().heap_bytes()
    );

    // Honor an env-armed injector; otherwise install the demo's default
    // mixed fault load (same spec as the CI overload-soak leg).
    let _guard = if fault::active().armed() {
        println!("faults: honoring HBFP_FAULT from the environment");
        None
    } else {
        println!("faults: worker-panic:0.35 slow-worker:0.5 nan-activation:0.05 slow-request:0.25");
        Some(fault::install(FaultInjector::from_specs(&[
            FaultSpec { site: FaultSite::WorkerPanic, rate: 0.35, seed: 11 },
            FaultSpec { site: FaultSite::SlowWorker, rate: 0.5, seed: 11 },
            FaultSpec { site: FaultSite::NanActivation, rate: 0.05, seed: 11 },
            FaultSpec { site: FaultSite::SlowRequest, rate: 0.25, seed: 11 },
        ])))
    };

    // Overload burst: 105 single-row requests at roughly twice what the
    // shed watermark admits, mixed deadlines, a poisoned payload every
    // 13th. Pump every 6 submissions.
    println!("\nburst: 105 requests, pump every 6 (max 16 rows per batch)");
    let mut submitted = 0u64;
    for i in 0..105u64 {
        let mut x: Vec<f32> = (0..k).map(|j| ((j as f32) * 0.31 + i as f32 * 0.77).cos()).collect();
        if i % 13 == 12 {
            x[2] = f32::NAN;
        }
        let deadline = match i % 7 {
            0 => Some(300),
            3 => Some(6_000),
            _ => None,
        };
        match srv.submit(model, x, deadline)? {
            Submission::Admitted { .. } => {}
            Submission::Rejected(why) => {
                if submitted % 10 == 0 {
                    println!("  request {i}: rejected ({why}) at depth {}", srv.queue_depth());
                }
            }
        }
        submitted += 1;
        if i % 6 == 5 {
            let rep = srv.pump()?;
            if let Some(b) = rep.batch {
                if b.degraded || b.split_fallback {
                    println!(
                        "  batch: {} rows @ {} bits{}{}",
                        b.ids.len(),
                        b.bits,
                        if b.degraded { " [degraded]" } else { "" },
                        if b.split_fallback { " [split-fallback]" } else { "" },
                    );
                }
            }
        }
    }
    srv.run_until_idle()?;

    // Settle the coda case: a request that dies waiting in the queue.
    srv.submit(model, vec![0.25; k], Some(300))?;
    clock.advance(400);
    srv.run_until_idle()?;

    let mut served = 0usize;
    let mut degraded = 0usize;
    let mut expired = 0usize;
    let mut failed = 0usize;
    for c in srv.drain_completions() {
        match c.outcome {
            Outcome::Served(r) => {
                served += 1;
                if r.degraded {
                    degraded += 1;
                }
            }
            Outcome::Expired(_) => expired += 1,
            Outcome::Failed(_) => failed += 1,
        }
    }
    let m = srv.metrics();
    println!(
        "\noutcomes: {served} served ({degraded} degraded), {expired} expired, {failed} failed"
    );
    println!(
        "rejected: {} (queue-full {}, overloaded {}, shedding {})",
        m.rejected_total(),
        m.rejected_queue_full,
        m.rejected_overloaded,
        m.rejected_shedding
    );
    println!(
        "faults: {} panics contained, {} retries, {} split fallbacks, {} slow requests",
        m.panics_contained, m.gemm_retries, m.split_fallbacks, m.slow_requests
    );
    println!(
        "latency ticks: p50 {} p95 {} p99 {} max {} over {} served",
        m.latency.p50(),
        m.latency.p95(),
        m.latency.p99(),
        m.latency.max(),
        m.latency.count()
    );

    println!("\nmetrics json:\n{}", srv.metrics_json());
    Ok(())
}
