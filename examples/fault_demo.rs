//! Fault-tolerance walkthrough: watch the resilient training loop
//! detect an injected mid-run NaN and a truncated checkpoint, roll back,
//! widen the mantissa class, and finish with a clean metrics history.
//!
//!     cargo run --release --example fault_demo
//!
//! Knobs (all optional):
//!
//!     HBFP_FAULT=nan-activation:1.0:3   inject via the env harness instead
//!     HBFP_THREADS=4                    worker budget for the BFP datapath
//!
//! The same scenario runs as the acceptance test in
//! `tests/fault_tolerance.rs::nan_plus_truncated_checkpoint_recovers_and_finishes`.

use anyhow::Result;
use hbfp::coordinator::checkpoint::{Checkpoint, CheckpointStore};
use hbfp::coordinator::config::LrSchedule;
use hbfp::coordinator::resilient::{run_resilient, FaultTolerantModel, SoftmaxDemo};
use hbfp::coordinator::RunConfig;
use hbfp::util::fault::{self, FaultInjector, FaultSite, FaultSpec};

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join(format!("hbfp_fault_demo_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let combo = "demo-centroids-hbfp8";
    let mut cfg = RunConfig::new(combo, 10)
        .with_seed(42)
        .with_lr(LrSchedule::Constant { lr: 0.5 })
        .with_checkpoint_every(5)
        .with_max_recoveries(4);
    cfg.checkpoint_dir = Some(dir.clone());

    // Phase 1: a clean 10-step run leaves `latest` (step 10) and `prev`
    // (step 5) crash-safe checkpoints behind.
    println!("phase 1: clean run, rotating checkpoints every 5 steps");
    let guard = fault::install(FaultInjector::none());
    let mut model = SoftmaxDemo::new(cfg.seed, 8);
    let h1 = run_resilient(&mut model, &cfg)?;
    println!(
        "  {} steps, final loss {:.4}, width {} bits",
        h1.steps.len(),
        h1.steps.last().map(|s| s.loss).unwrap_or(f32::NAN),
        model.width()
    );
    drop(guard);

    // Simulate a crash mid-write: truncate the latest checkpoint.
    let store = CheckpointStore::new(dir.clone(), combo);
    let latest = store.latest_path();
    let bytes = std::fs::read(&latest)?;
    std::fs::write(&latest, &bytes[..bytes.len() - 7])?;
    println!(
        "phase 2: truncated {} ({} -> {} bytes); load now fails: {}",
        latest.display(),
        bytes.len(),
        bytes.len() - 7,
        Checkpoint::load(&latest).err().map(|e| e.to_string()).unwrap_or_default()
    );

    // Phase 2: resume for 10 more steps with a NaN activation injected at
    // the narrow (8-bit) width class. Expect: resume from `prev` (the
    // corrupt `latest` is skipped), NaN on the first step, rollback +
    // widen to 16 bits, then a clean finish.
    let guard = if fault::active().armed() {
        None // honour an HBFP_FAULT the caller set
    } else {
        Some(fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::NanActivation,
            rate: 1.0,
            seed: 3,
        }])))
    };
    cfg.steps = 20;
    let mut model = SoftmaxDemo::new(cfg.seed, 8);
    let h2 = run_resilient(&mut model, &cfg)?;
    drop(guard);

    println!(
        "  resumed at step {}, finished at step {}, width {} bits, diverged: {}",
        h2.steps.first().map(|s| s.step).unwrap_or(0),
        h2.steps.last().map(|s| s.step).unwrap_or(0),
        model.width(),
        h2.diverged()
    );
    println!("  guard stats: {} scans, {} fp32 fallbacks", model.stats.scans(), model.stats.fp32_fallbacks());
    println!("  recovery events:");
    for r in &h2.recoveries {
        println!("    step {:>3}  {:<18} {:<15} {}", r.step, r.kind.name(), r.action.name(), r.detail);
    }

    let csv = dir.join("history.csv");
    h2.write_csv(&csv)?;
    println!("  history (recovery rows included) written to {}", csv.display());
    Ok(())
}
